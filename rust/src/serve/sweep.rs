//! The load-vs-latency sweep: the serving counterpart of the cluster
//! scaling curve. Offered load climbs a ladder of fractions of the
//! batch-mode roofline; each rung is one full serving simulation, and the
//! folded points show the classic saturation picture — flat latency at
//! low load, a knee near the roofline, and queueing blow-up past it.

use super::batcher::BatchPolicy;
use super::engine::{Server, Workload};
use super::request::{TraceConfig, TraceShape};
use super::stats::percentile;
use crate::metrics::report::render_table;
use crate::pipeline::core::SimError;

/// One rung of the load ladder, folded from a full [`Server::serve_trace`]
/// run.
#[derive(Debug, Clone, Copy)]
pub struct LoadPoint {
    /// Configured offered load for this rung, in requests per second.
    pub offered_rps: f64,
    /// Achieved throughput over the run's span.
    pub achieved_rps: f64,
    /// Median latency in milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile latency in milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile (tail) latency in milliseconds.
    pub p99_ms: f64,
    /// Mean latency in milliseconds.
    pub mean_ms: f64,
    /// Fraction of the span the cluster was executing.
    pub utilization: f64,
    /// Fraction of aggregate DIMC-tile capacity doing useful work.
    pub tile_utilization: f64,
    /// Time-weighted mean queue depth.
    pub mean_queue_depth: f64,
    /// Mean dispatched batch size.
    pub mean_batch: f64,
}

/// The default ladder: fractions of the roofline spanning comfortable
/// load to 25% past saturation.
pub fn rps_ladder(roofline_rps: f64) -> Vec<f64> {
    [0.1, 0.25, 0.5, 0.75, 0.9, 1.05, 1.25].iter().map(|f| f * roofline_rps).collect()
}

/// Run one serving simulation per rung of `ladder` (same trace shape,
/// seed, request count and batching policy throughout) and fold each into
/// a [`LoadPoint`]. The server's service-time caches stay warm across
/// rungs, so the sweep costs little more than its slowest rung.
pub fn load_sweep(
    server: &mut Server,
    workloads: &[Workload],
    policy: BatchPolicy,
    shape: TraceShape,
    seed: u64,
    requests: usize,
    ladder: &[f64],
) -> Result<Vec<LoadPoint>, SimError> {
    let mut points = Vec::with_capacity(ladder.len());
    for &rps in ladder {
        let trace = TraceConfig { rps, requests, shape, seed };
        let rep = server.serve_trace(workloads, policy, &trace)?;
        let lat = rep.latencies_sorted(); // sort once, read three ranks
        points.push(LoadPoint {
            offered_rps: rps,
            achieved_rps: rep.achieved_rps(),
            p50_ms: rep.ms(percentile(&lat, 50.0)),
            p95_ms: rep.ms(percentile(&lat, 95.0)),
            p99_ms: rep.ms(percentile(&lat, 99.0)),
            mean_ms: rep.mean_latency_ms(),
            utilization: rep.utilization(),
            tile_utilization: rep.tile_utilization(),
            mean_queue_depth: rep.mean_queue_depth,
            mean_batch: rep.mean_batch_size(),
        });
    }
    Ok(points)
}

/// Render a sweep as an aligned text table.
pub fn render(title: &str, points: &[LoadPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                format!("{:.0}", p.offered_rps),
                format!("{:.0}", p.achieved_rps),
                format!("{:.3}", p.p50_ms),
                format!("{:.3}", p.p95_ms),
                format!("{:.3}", p.p99_ms),
                format!("{:.2}", p.mean_queue_depth),
                format!("{:.2}", p.mean_batch),
                format!("{:.0}%", p.utilization * 100.0),
                format!("{:.0}%", p.tile_utilization * 100.0),
            ]
        })
        .collect();
    render_table(
        title,
        &[
            "offered r/s",
            "achieved r/s",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "depth",
            "batch",
            "busy",
            "tile util",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::compiler::layer::LayerConfig;
    use crate::dimc::Precision;

    fn tiny() -> Vec<Workload> {
        vec![Workload::new(
            "tiny",
            vec![LayerConfig::conv("t1", 16, 64, 3, 3, 8, 8, 1, 1)],
        )]
    }

    #[test]
    fn sweep_shows_saturation() {
        let zoo = tiny();
        let mut srv = Server::new(Arch::default(), Precision::Int4, 4);
        let policy = BatchPolicy { max_batch: 4, max_wait_cycles: 0 };
        let roof = srv.batch_roofline(&zoo, 0, policy.max_batch).unwrap();
        let pts = load_sweep(
            &mut srv,
            &zoo,
            policy,
            TraceShape::Uniform,
            0xA11CE,
            300,
            &rps_ladder(roof),
        )
        .unwrap();
        assert_eq!(pts.len(), 7);
        // Low load: negligible queueing, latency near the service floor.
        assert!(pts[0].mean_queue_depth < 0.5, "idle rung queued {:.2}", pts[0].mean_queue_depth);
        // Past the roofline the system saturates: achieved < offered and
        // the tail inflates well beyond the low-load tail.
        let last = pts.last().unwrap();
        assert!(last.achieved_rps < last.offered_rps * 0.98);
        assert!(last.achieved_rps <= roof * 1.02, "achieved above roofline");
        assert!(last.p99_ms > pts[0].p99_ms, "tail latency did not grow with load");
        assert!(last.mean_batch > pts[0].mean_batch, "batches did not grow with load");
    }

    #[test]
    fn render_has_all_rungs() {
        let zoo = tiny();
        let mut srv = Server::new(Arch::default(), Precision::Int4, 2);
        let pts = load_sweep(
            &mut srv,
            &zoo,
            BatchPolicy::default(),
            TraceShape::Bursty,
            7,
            80,
            &[500.0, 5000.0],
        )
        .unwrap();
        let t = render("demo serve", &pts);
        assert!(t.contains("== demo serve =="));
        assert!(t.lines().count() >= 4);
    }
}
