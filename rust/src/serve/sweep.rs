//! The load-vs-latency sweep: the serving counterpart of the cluster
//! scaling curve. Offered load climbs a ladder of fractions of the
//! batch-mode roofline; each rung is one full serving simulation, and the
//! folded points show the classic saturation picture — flat latency at
//! low load, a knee near the roofline, and queueing blow-up past it. In
//! decode-phase serving each rung additionally folds the token-level
//! tails: time-to-first-token and inter-token latency percentiles.

use super::engine::{Server, Workload};
use super::spec::{ServePhase, TrafficSpec};
use super::stats::percentile;
use crate::metrics::report::render_table;
use crate::pipeline::core::SimError;

/// One rung of the load ladder, folded from a full serving run.
#[derive(Debug, Clone, Copy)]
pub struct LoadPoint {
    /// Configured offered load for this rung, in requests per second.
    pub offered_rps: f64,
    /// Achieved throughput over the run's span.
    pub achieved_rps: f64,
    /// Median latency in milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile latency in milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile (tail) latency in milliseconds.
    pub p99_ms: f64,
    /// Mean latency in milliseconds.
    pub mean_ms: f64,
    /// Fraction of the span the cluster was executing.
    pub utilization: f64,
    /// Fraction of aggregate DIMC-tile capacity doing useful work.
    pub tile_utilization: f64,
    /// Time-weighted mean queue depth.
    pub mean_queue_depth: f64,
    /// Mean dispatched batch size.
    pub mean_batch: f64,
    /// Median time-to-first-token in milliseconds (equals `p50_ms` in
    /// single-shot serving, where the only token is the completion).
    pub ttft_p50_ms: f64,
    /// 99th-percentile time-to-first-token in milliseconds.
    pub ttft_p99_ms: f64,
    /// Median inter-token latency in milliseconds (0 outside the decode
    /// phase — single-shot serving has no inter-token gaps).
    pub itl_p50_ms: f64,
    /// 99th-percentile inter-token latency in milliseconds.
    pub itl_p99_ms: f64,
}

/// The default ladder: fractions of the roofline spanning comfortable
/// load to 25% past saturation.
pub fn rps_ladder(roofline_rps: f64) -> Vec<f64> {
    [0.1, 0.25, 0.5, 0.75, 0.9, 1.05, 1.25].iter().map(|f| f * roofline_rps).collect()
}

/// Run one serving simulation per rung of `ladder` — `spec` with its
/// `rps` overridden per rung, dispatched to the phase the spec names —
/// and fold each into a [`LoadPoint`]. The server's service-time caches
/// stay warm across rungs, so the sweep costs little more than its
/// slowest rung.
pub fn load_sweep(
    server: &mut Server,
    workloads: &[Workload],
    spec: &TrafficSpec,
    ladder: &[f64],
) -> Result<Vec<LoadPoint>, SimError> {
    let mut points = Vec::with_capacity(ladder.len());
    for &rps in ladder {
        let rung = TrafficSpec { rps, ..*spec };
        let rep = match rung.phase {
            ServePhase::Batch => server.serve_trace(workloads, rung.policy(), &rung.trace())?,
            ServePhase::Decode => server.serve_decode_trace(workloads, &rung)?,
        };
        let lat = rep.latencies_sorted(); // sort once, read three ranks
        points.push(LoadPoint {
            offered_rps: rps,
            achieved_rps: rep.achieved_rps(),
            p50_ms: rep.ms(percentile(&lat, 50.0)),
            p95_ms: rep.ms(percentile(&lat, 95.0)),
            p99_ms: rep.ms(percentile(&lat, 99.0)),
            mean_ms: rep.mean_latency_ms(),
            utilization: rep.utilization(),
            tile_utilization: rep.tile_utilization(),
            mean_queue_depth: rep.mean_queue_depth,
            mean_batch: rep.mean_batch_size(),
            ttft_p50_ms: rep.ttft_ms(50.0),
            ttft_p99_ms: rep.ttft_ms(99.0),
            itl_p50_ms: rep.itl_ms(50.0),
            itl_p99_ms: rep.itl_ms(99.0),
        });
    }
    Ok(points)
}

/// Render a sweep as an aligned text table.
pub fn render(title: &str, points: &[LoadPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                format!("{:.0}", p.offered_rps),
                format!("{:.0}", p.achieved_rps),
                format!("{:.3}", p.p50_ms),
                format!("{:.3}", p.p95_ms),
                format!("{:.3}", p.p99_ms),
                format!("{:.3}", p.ttft_p50_ms),
                format!("{:.3}", p.ttft_p99_ms),
                format!("{:.3}", p.itl_p50_ms),
                format!("{:.3}", p.itl_p99_ms),
                format!("{:.2}", p.mean_queue_depth),
                format!("{:.2}", p.mean_batch),
                format!("{:.0}%", p.utilization * 100.0),
                format!("{:.0}%", p.tile_utilization * 100.0),
            ]
        })
        .collect();
    render_table(
        title,
        &[
            "offered r/s",
            "achieved r/s",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "ttft p50",
            "ttft p99",
            "itl p50",
            "itl p99",
            "depth",
            "batch",
            "busy",
            "tile util",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::compiler::layer::LayerConfig;
    use crate::dimc::Precision;
    use crate::serve::TraceShape;

    fn tiny() -> Vec<Workload> {
        vec![Workload::new(
            "tiny",
            vec![LayerConfig::conv("t1", 16, 64, 3, 3, 8, 8, 1, 1)],
        )]
    }

    #[test]
    fn sweep_shows_saturation() {
        let zoo = tiny();
        let mut srv = Server::new(Arch::default(), Precision::Int4, 4);
        let spec = TrafficSpec::at(0.0).requests(300).seed(0xA11CE).max_batch(4);
        let roof = srv.batch_roofline(&zoo, 0, spec.max_batch).unwrap();
        let pts = load_sweep(&mut srv, &zoo, &spec, &rps_ladder(roof)).unwrap();
        assert_eq!(pts.len(), 7);
        // Low load: negligible queueing, latency near the service floor.
        assert!(pts[0].mean_queue_depth < 0.5, "idle rung queued {:.2}", pts[0].mean_queue_depth);
        // In single-shot serving a request's only token is its
        // completion, so the TTFT columns equal the latency columns.
        assert_eq!(pts[0].ttft_p50_ms, pts[0].p50_ms);
        assert_eq!(pts[0].itl_p50_ms, 0.0, "no inter-token gaps outside decode");
        // Past the roofline the system saturates: achieved < offered and
        // the tail inflates well beyond the low-load tail.
        let last = pts.last().unwrap();
        assert!(last.achieved_rps < last.offered_rps * 0.98);
        assert!(last.achieved_rps <= roof * 1.02, "achieved above roofline");
        assert!(last.p99_ms > pts[0].p99_ms, "tail latency did not grow with load");
        assert!(last.mean_batch > pts[0].mean_batch, "batches did not grow with load");
    }

    #[test]
    fn decode_sweep_reports_token_tails() {
        let zoo = vec![Workload::new("mobilebert", crate::workloads::bert::mobilebert())];
        let mut srv = Server::new(Arch::default(), Precision::Int4, 2);
        let spec = TrafficSpec::at(0.0)
            .requests(4)
            .max_batch(2)
            .phase(ServePhase::Decode)
            .decode_tokens(2);
        let pts = load_sweep(&mut srv, &zoo, &spec, &[200.0, 2000.0]).unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.ttft_p50_ms > 0.0);
            assert!(p.itl_p50_ms > 0.0, "decode rungs must fold ITL samples");
            assert!(p.ttft_p99_ms >= p.ttft_p50_ms);
            assert!(p.itl_p99_ms >= p.itl_p50_ms);
        }
    }

    #[test]
    fn render_has_all_rungs() {
        let zoo = tiny();
        let mut srv = Server::new(Arch::default(), Precision::Int4, 2);
        let spec = TrafficSpec::at(0.0).requests(80).shape(TraceShape::Bursty).seed(7);
        let pts = load_sweep(&mut srv, &zoo, &spec, &[500.0, 5000.0]).unwrap();
        let t = render("demo serve", &pts);
        assert!(t.contains("== demo serve =="));
        assert!(t.lines().count() >= 4);
    }
}
