//! Deterministic request generation: seeded arrival traces over a model
//! mix.
//!
//! A trace is a finite, time-ordered list of [`Request`]s. Arrival gaps
//! are exponentially distributed (Poisson traffic) around the configured
//! mean rate, drawn from the repo's deterministic
//! [`Lcg`](crate::compiler::pack::Lcg) so a `(shape, seed, rps, n)`
//! quadruple always produces the identical trace — the serving simulator
//! never touches a wall clock. Three shapes model the traffic patterns a
//! production deployment sees:
//!
//! * [`TraceShape::Uniform`] — steady Poisson arrivals at the mean rate;
//! * [`TraceShape::Bursty`] — alternating on/off phases (4x the mean rate
//!   inside a burst, 4/7 of it between bursts) with the same long-run mean;
//! * [`TraceShape::Ramp`] — a diurnal ramp: the instantaneous rate climbs
//!   linearly from 0.5x to 1.5x of the mean across the trace.

use crate::compiler::pack::Lcg;

/// Requests per burst phase of the [`TraceShape::Bursty`] trace.
pub const BURST_LEN: u64 = 16;

/// The shape of an arrival trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceShape {
    /// Steady Poisson arrivals at the configured mean rate.
    Uniform,
    /// On/off phases: 4x the mean rate for [`BURST_LEN`] requests, then a
    /// slow phase that restores the long-run mean.
    Bursty,
    /// Diurnal ramp: instantaneous rate grows linearly from 0.5x to 1.5x
    /// of the mean over the trace.
    Ramp,
}

impl TraceShape {
    /// Parse a CLI trace name (`uniform` / `bursty` / `ramp`).
    pub fn parse(s: &str) -> Option<TraceShape> {
        match s {
            "uniform" => Some(TraceShape::Uniform),
            "bursty" => Some(TraceShape::Bursty),
            "ramp" => Some(TraceShape::Ramp),
            _ => None,
        }
    }

    /// The canonical CLI name of the shape.
    pub fn as_str(&self) -> &'static str {
        match self {
            TraceShape::Uniform => "uniform",
            TraceShape::Bursty => "bursty",
            TraceShape::Ramp => "ramp",
        }
    }
}

/// One inference request: which model it wants and when it arrived.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique, dense id (`0..n` in arrival order).
    pub id: u64,
    /// Index into the served workload set.
    pub model: usize,
    /// Arrival time in core cycles.
    pub arrival: u64,
}

/// Parameters of one generated trace. The clock that converts the rate
/// to cycles is *not* part of the config — the server supplies its own
/// [`Arch`](crate::arch::Arch) clock, so arrivals and service times can
/// never desynchronize.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Mean offered load in requests per second.
    pub rps: f64,
    /// Number of requests to generate.
    pub requests: usize,
    /// Arrival pattern.
    pub shape: TraceShape,
    /// Lcg seed; the same seed always reproduces the same trace.
    pub seed: u64,
}

/// Exponential gap with the given mean, in cycles (>= 1).
fn exp_gap(r: &mut Lcg, mean_cycles: f64) -> u64 {
    // 53 uniform bits -> u in [0, 1); -ln(1 - u) is Exp(1).
    let u = (r.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0);
    (mean_cycles * -(1.0 - u).ln()).round().max(1.0) as u64
}

/// Weighted model draw over (already validated) non-negative weights.
fn pick_model(r: &mut Lcg, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    let u = (r.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0);
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w / total;
        if u < acc {
            return i;
        }
    }
    weights.len() - 1
}

/// Empirical offered load of a time-ordered arrival list, in requests
/// per second. `None` when the rate is undefined: a zero- or one-request
/// trace has no inter-arrival gap (indexing the tail of such a trace is
/// exactly the panic this helper replaces), and a degenerate trace whose
/// requests all share one arrival cycle has no measurable span.
pub fn empirical_rps(arrivals: &[Request], clock_hz: f64) -> Option<f64> {
    let (first, last) = (arrivals.first()?, arrivals.last()?);
    if last.arrival <= first.arrival {
        return None;
    }
    let span = (last.arrival - first.arrival) as f64;
    Some((arrivals.len() - 1) as f64 * clock_hz / span)
}

/// Generate a time-ordered trace of `cfg.requests` requests whose model is
/// drawn per-request from `weights` (one non-negative weight per served
/// model; they need not sum to 1). `clock_hz` converts the configured
/// rate to cycles.
pub fn generate(cfg: &TraceConfig, weights: &[f64], clock_hz: f64) -> Vec<Request> {
    assert!(!weights.is_empty(), "need at least one served model");
    assert!(weights.iter().all(|w| *w >= 0.0) && weights.iter().sum::<f64>() > 0.0);
    let mean = clock_hz / cfg.rps.max(1e-9); // mean gap in cycles
    let n = cfg.requests;
    let mut r = Lcg::new(cfg.seed);
    let mut out = Vec::with_capacity(n);
    let mut at = 0u64;
    for i in 0..n as u64 {
        let gap_mean = match cfg.shape {
            TraceShape::Uniform => mean,
            TraceShape::Bursty => {
                // Alternate burst (4x rate) and lull (4/7 rate) phases of
                // BURST_LEN requests each; the phase means average to 1.
                if (i / BURST_LEN) % 2 == 0 {
                    mean / 4.0
                } else {
                    mean * 7.0 / 4.0
                }
            }
            TraceShape::Ramp => {
                let frac = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.5 };
                mean / (0.5 + frac) // instantaneous rate 0.5x..1.5x
            }
        };
        // Saturate rather than wrap so absurdly low rates still yield a
        // sorted (if degenerate) trace.
        at = at.saturating_add(exp_gap(&mut r, gap_mean));
        out.push(Request { id: i, model: pick_model(&mut r, weights), arrival: at });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLOCK_HZ: f64 = 500e6;

    fn cfg(shape: TraceShape) -> TraceConfig {
        TraceConfig { rps: 1000.0, requests: 400, shape, seed: 0x5EED }
    }

    #[test]
    fn traces_are_deterministic_and_ordered() {
        for shape in [TraceShape::Uniform, TraceShape::Bursty, TraceShape::Ramp] {
            let a = generate(&cfg(shape), &[1.0], CLOCK_HZ);
            let b = generate(&cfg(shape), &[1.0], CLOCK_HZ);
            assert_eq!(a.len(), 400);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival, y.arrival);
                assert_eq!(x.model, y.model);
            }
            assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival), "unsorted");
            assert!(a.windows(2).all(|w| w[0].id + 1 == w[1].id), "ids not dense");
        }
    }

    #[test]
    fn mean_rate_is_close_to_configured() {
        for shape in [TraceShape::Uniform, TraceShape::Bursty] {
            let c = cfg(shape);
            let t = generate(&c, &[1.0], CLOCK_HZ);
            let rate = empirical_rps(&t, CLOCK_HZ).unwrap();
            assert!(
                (rate / c.rps - 1.0).abs() < 0.25,
                "{}: empirical {rate:.0} vs configured {:.0}",
                shape.as_str(),
                c.rps
            );
        }
    }

    #[test]
    fn bursty_is_burstier_than_uniform() {
        // Compare the p95/p50 gap ratio: bursts create many short gaps and
        // a few very long ones.
        let spread = |shape| {
            let t = generate(&cfg(shape), &[1.0], CLOCK_HZ);
            let mut gaps: Vec<u64> =
                t.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
            gaps.sort_unstable();
            gaps[gaps.len() * 95 / 100] as f64 / gaps[gaps.len() / 2].max(1) as f64
        };
        assert!(spread(TraceShape::Bursty) > spread(TraceShape::Uniform));
    }

    #[test]
    fn ramp_accelerates() {
        let t = generate(&cfg(TraceShape::Ramp), &[1.0], CLOCK_HZ);
        let half = t.len() / 2;
        // The second half carries the same request count over a shorter
        // span, so its empirical rate must be higher.
        let slow = empirical_rps(&t[..half], CLOCK_HZ).unwrap();
        let fast = empirical_rps(&t[half..], CLOCK_HZ).unwrap();
        assert!(fast > slow, "ramp second half {fast:.0} r/s not faster than first {slow:.0}");
    }

    #[test]
    fn empirical_rate_of_degenerate_traces_is_none() {
        // Regression: the old inline computation indexed the trace tail
        // and panicked on zero- and one-request traces.
        assert_eq!(empirical_rps(&[], CLOCK_HZ), None);
        let one = vec![Request { id: 0, model: 0, arrival: 42 }];
        assert_eq!(empirical_rps(&one, CLOCK_HZ), None);
        let flat = vec![
            Request { id: 0, model: 0, arrival: 42 },
            Request { id: 1, model: 0, arrival: 42 },
        ];
        assert_eq!(empirical_rps(&flat, CLOCK_HZ), None, "zero span has no rate");
        let t = generate(&cfg(TraceShape::Uniform), &[1.0], CLOCK_HZ);
        assert!(empirical_rps(&t, CLOCK_HZ).is_some());
    }

    #[test]
    fn mix_draws_every_model_roughly_in_proportion() {
        let t = generate(&cfg(TraceShape::Uniform), &[3.0, 1.0], CLOCK_HZ);
        let m0 = t.iter().filter(|r| r.model == 0).count() as f64;
        let frac = m0 / t.len() as f64;
        assert!((0.6..0.9).contains(&frac), "model 0 drew {frac:.2} of traffic");
    }

    #[test]
    fn trace_shape_round_trips_through_parse() {
        for shape in [TraceShape::Uniform, TraceShape::Bursty, TraceShape::Ramp] {
            assert_eq!(TraceShape::parse(shape.as_str()), Some(shape));
        }
        assert_eq!(TraceShape::parse("nope"), None);
    }
}
