//! The dynamic batcher: per-model FIFO queues plus the dispatch window
//! policy.
//!
//! A batch of requests for the *same* model becomes eligible for dispatch
//! when either
//!
//! * the queue holds [`BatchPolicy::max_batch`] requests (a full batch), or
//! * the model's oldest queued request has waited
//!   [`BatchPolicy::max_wait_cycles`] cycles (the window expired).
//!
//! With `max_wait_cycles = 0` the batcher is *greedy*: a request on an
//! idle server dispatches the cycle it arrives, so zero-load latency is
//! exactly the unbatched cluster latency (property-tested in
//! `rust/tests/prop_serve.rs`). Under load, batches still form naturally
//! from the backlog that accumulates while the cluster is busy. A non-zero
//! window additionally *holds* a sub-full batch to trade latency for
//! throughput, exactly like production serving systems.

use super::request::Request;
use std::collections::VecDeque;

/// The two knobs of the dynamic batching window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Largest batch ever dispatched (also the roofline batch size).
    pub max_batch: u32,
    /// Longest a request may head its queue before dispatch is forced.
    pub max_wait_cycles: u64,
}

impl Default for BatchPolicy {
    /// Greedy default: batches of up to 8 with no artificial hold.
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait_cycles: 0 }
    }
}

/// Per-model FIFO queues implementing the window policy. Purely
/// mechanical — time is whatever the discrete-event engine says it is.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queues: Vec<VecDeque<Request>>,
}

impl Batcher {
    /// An empty batcher for `models` served models.
    pub fn new(policy: BatchPolicy, models: usize) -> Self {
        Batcher { policy, queues: (0..models).map(|_| VecDeque::new()).collect() }
    }

    /// Admit one request to its model's queue.
    pub fn enqueue(&mut self, r: Request) {
        self.queues[r.model].push_back(r);
    }

    /// Total queued requests across all models.
    pub fn depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Whether a model's queue is dispatch-eligible at `now`.
    fn eligible(&self, model: usize, now: u64) -> bool {
        let q = &self.queues[model];
        match q.front() {
            None => false,
            Some(head) => {
                q.len() as u32 >= self.policy.max_batch
                    || now >= head.arrival.saturating_add(self.policy.max_wait_cycles)
            }
        }
    }

    /// The model to dispatch at `now`, if any: among all eligible queues,
    /// the one whose head request is oldest (FIFO across models; ties
    /// break toward the lower model index).
    pub fn ready(&self, now: u64) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&m| self.eligible(m, now))
            .min_by_key(|&m| self.queues[m].front().map(|r| r.arrival).unwrap_or(u64::MAX))
    }

    /// The earliest cycle at which some queue becomes dispatch-eligible,
    /// assuming no further arrivals; `None` when every queue is empty.
    /// A full queue is eligible immediately (returns 0).
    pub fn ready_at(&self) -> Option<u64> {
        self.queues
            .iter()
            .filter_map(|q| {
                q.front().map(|head| {
                    if q.len() as u32 >= self.policy.max_batch {
                        0
                    } else {
                        head.arrival.saturating_add(self.policy.max_wait_cycles)
                    }
                })
            })
            .min()
    }

    /// The model whose head request is oldest, regardless of window
    /// eligibility — the flush target when no further event can ever
    /// make a queue eligible (see the engine's end-of-trace flush).
    pub fn oldest_head(&self) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&m| !self.queues[m].is_empty())
            .min_by_key(|&m| self.queues[m].front().map(|r| r.arrival).unwrap_or(u64::MAX))
    }

    /// Remove and return up to `max_batch` oldest requests of `model`.
    pub fn take_batch(&mut self, model: usize) -> Vec<Request> {
        self.take_up_to(model, self.policy.max_batch)
    }

    /// Remove and return up to `cap` oldest requests of `model`, still
    /// capped by the window's `max_batch`. The continuous batcher's
    /// slot-limited admission: `cap` is however many in-flight slots the
    /// model has free.
    pub fn take_up_to(&mut self, model: usize, cap: u32) -> Vec<Request> {
        let q = &mut self.queues[model];
        let n = (q.len() as u32).min(self.policy.max_batch).min(cap) as usize;
        q.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, arrival: u64) -> Request {
        Request { id, model, arrival }
    }

    #[test]
    fn full_batch_is_immediately_ready() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait_cycles: 1000 }, 1);
        b.enqueue(req(0, 0, 10));
        assert_eq!(b.ready(10), None, "sub-full batch must hold for the window");
        assert_eq!(b.ready_at(), Some(1010));
        b.enqueue(req(1, 0, 20));
        assert_eq!(b.ready(20), Some(0), "full batch dispatches at once");
        assert_eq!(b.ready_at(), Some(0));
        let batch = b.take_batch(0);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id, 0, "FIFO order");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn window_expiry_forces_dispatch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_cycles: 100 }, 1);
        b.enqueue(req(0, 0, 50));
        assert_eq!(b.ready(149), None);
        assert_eq!(b.ready(150), Some(0));
    }

    #[test]
    fn greedy_policy_dispatches_at_arrival() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_cycles: 0 }, 1);
        b.enqueue(req(0, 0, 7));
        assert_eq!(b.ready(7), Some(0));
    }

    #[test]
    fn oldest_head_wins_across_models() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait_cycles: 0 }, 2);
        b.enqueue(req(0, 1, 5));
        b.enqueue(req(1, 0, 9));
        assert_eq!(b.ready(9), Some(1), "model 1's head arrived first");
        b.take_batch(1);
        assert_eq!(b.ready(9), Some(0));
    }

    #[test]
    fn take_batch_caps_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait_cycles: 0 }, 1);
        for i in 0..5 {
            b.enqueue(req(i, 0, i));
        }
        assert_eq!(b.take_batch(0).len(), 3);
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn take_up_to_respects_both_caps() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait_cycles: 0 }, 1);
        for i in 0..5 {
            b.enqueue(req(i, 0, i));
        }
        let got = b.take_up_to(0, 2);
        assert_eq!(got.len(), 2, "slot cap below max_batch wins");
        assert_eq!(got[0].id, 0, "FIFO order");
        assert_eq!(b.take_up_to(0, 8).len(), 3, "max_batch still caps a large slot count");
        assert_eq!(b.depth(), 0);
    }
}
