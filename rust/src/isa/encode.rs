//! Bit-level instruction encoding.
//!
//! Scalar and vector instructions use the standard RV32I/M and RVV 1.0
//! encodings. The four custom DIMC instructions use the *custom-0* major
//! opcode (0b000_1011) with the following normative field layout (Fig. 4 of
//! the paper; the preprint's figure is partially garbled so this crate's
//! layout is the reference):
//!
//! ```text
//! DL.I : | nvec-1 [31:30] | 0 [29] | mask [28:25] | vs1 [24:20] |
//!        | width [19:18] | 0 [17] | sec [16:15] | 000 [14:12] |
//!        | 00000 [11:7] | 0001011 |
//! DL.M : same, funct3 = 001, m_row in [11:7]
//! DC.P : | sh [31] | dh [30] | m_row [29:25] | vs1 [24:20] |
//!        | width [19:18] | 000 [17:15] | 010 [14:12] | vd [11:7] | 0001011 |
//! DC.F : same, funct3 = 011, bidx (nibble index 0..7) in [17:15]
//! ```
//!
//! `width` is the precision field: 0 = 4-bit, 1 = 2-bit, 2 = 1-bit for the
//! compute instructions, and the reserved element-width hint for the loads.

use super::{AluOp, BranchCond, Instr};

pub const OPC_LUI: u32 = 0b0110111;
pub const OPC_AUIPC: u32 = 0b0010111;
pub const OPC_OP_IMM: u32 = 0b0010011;
pub const OPC_OP: u32 = 0b0110011;
pub const OPC_LOAD: u32 = 0b0000011;
pub const OPC_STORE: u32 = 0b0100011;
pub const OPC_BRANCH: u32 = 0b1100011;
pub const OPC_JAL: u32 = 0b1101111;
pub const OPC_JALR: u32 = 0b1100111;
pub const OPC_SYSTEM: u32 = 0b1110011;
pub const OPC_V: u32 = 0b1010111;
pub const OPC_VL: u32 = 0b0000111;
pub const OPC_VS: u32 = 0b0100111;
/// RISC-V custom-0: reserved for non-standard extensions — the paper maps
/// DL.I / DL.M / DC.P / DC.F here to avoid any conflict with RVV.
pub const OPC_CUSTOM0: u32 = 0b0001011;

pub const F3_DLI: u32 = 0b000;
pub const F3_DLM: u32 = 0b001;
pub const F3_DCP: u32 = 0b010;
pub const F3_DCF: u32 = 0b011;

// OP-V funct3 minor opcodes.
pub const OPIVV: u32 = 0b000;
pub const OPIVI: u32 = 0b011;
pub const OPIVX: u32 = 0b100;
pub const OPMVV: u32 = 0b010;

#[inline]
fn r_type(funct7: u32, rs2: u32, rs1: u32, funct3: u32, rd: u32, opc: u32) -> u32 {
    (funct7 << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opc
}

#[inline]
fn i_type(imm: i32, rs1: u32, funct3: u32, rd: u32, opc: u32) -> u32 {
    (((imm as u32) & 0xfff) << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | opc
}

#[inline]
fn s_type(imm: i32, rs2: u32, rs1: u32, funct3: u32, opc: u32) -> u32 {
    let imm = imm as u32;
    ((imm >> 5 & 0x7f) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (funct3 << 12)
        | ((imm & 0x1f) << 7)
        | opc
}

#[inline]
fn b_type(off: i32, rs2: u32, rs1: u32, funct3: u32) -> u32 {
    let o = off as u32;
    ((o >> 12 & 1) << 31)
        | ((o >> 5 & 0x3f) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (funct3 << 12)
        | ((o >> 1 & 0xf) << 8)
        | ((o >> 11 & 1) << 7)
        | OPC_BRANCH
}

#[inline]
fn j_type(off: i32, rd: u32) -> u32 {
    let o = off as u32;
    ((o >> 20 & 1) << 31)
        | ((o >> 1 & 0x3ff) << 21)
        | ((o >> 11 & 1) << 20)
        | ((o >> 12 & 0xff) << 12)
        | (rd << 7)
        | OPC_JAL
}

/// OP-V arithmetic: funct6 | vm=1 | vs2 | src | funct3 | vd | OPC_V.
#[inline]
fn v_arith(funct6: u32, vs2: u32, src: u32, funct3: u32, vd: u32) -> u32 {
    (funct6 << 26) | (1 << 25) | (vs2 << 20) | (src << 15) | (funct3 << 12) | (vd << 7) | OPC_V
}

fn vl_width_bits(eew: u8) -> u32 {
    match eew {
        8 => 0b000,
        16 => 0b101,
        32 => 0b110,
        _ => panic!("unsupported eew {eew}"),
    }
}

fn alu_funct3(op: AluOp) -> u32 {
    match op {
        AluOp::Add | AluOp::Sub => 0b000,
        AluOp::Sll => 0b001,
        AluOp::Slt => 0b010,
        AluOp::Sltu => 0b011,
        AluOp::Xor => 0b100,
        AluOp::Srl | AluOp::Sra => 0b101,
        AluOp::Or => 0b110,
        AluOp::And => 0b111,
        AluOp::Mul => 0b000,
    }
}

fn branch_funct3(c: BranchCond) -> u32 {
    match c {
        BranchCond::Eq => 0b000,
        BranchCond::Ne => 0b001,
        BranchCond::Lt => 0b100,
        BranchCond::Ge => 0b101,
        BranchCond::Ltu => 0b110,
        BranchCond::Geu => 0b111,
    }
}

/// Encode one instruction into its 32-bit machine word.
pub fn encode(i: &Instr) -> u32 {
    use Instr::*;
    match *i {
        Lui { rd, imm } => (((imm as u32) & 0xfffff) << 12) | ((rd as u32) << 7) | OPC_LUI,
        Auipc { rd, imm } => (((imm as u32) & 0xfffff) << 12) | ((rd as u32) << 7) | OPC_AUIPC,
        OpImm { op, rd, rs1, imm } => {
            assert!(op != AluOp::Mul && op != AluOp::Sub, "no {op:?} immediate form");
            match op {
                AluOp::Sll | AluOp::Srl => r_type(
                    0,
                    (imm as u32) & 0x1f,
                    rs1 as u32,
                    alu_funct3(op),
                    rd as u32,
                    OPC_OP_IMM,
                ),
                AluOp::Sra => r_type(
                    0b0100000,
                    (imm as u32) & 0x1f,
                    rs1 as u32,
                    alu_funct3(op),
                    rd as u32,
                    OPC_OP_IMM,
                ),
                _ => i_type(imm, rs1 as u32, alu_funct3(op), rd as u32, OPC_OP_IMM),
            }
        }
        Op { op, rd, rs1, rs2 } => {
            let funct7 = match op {
                AluOp::Sub | AluOp::Sra => 0b0100000,
                AluOp::Mul => 0b0000001,
                _ => 0,
            };
            r_type(funct7, rs2 as u32, rs1 as u32, alu_funct3(op), rd as u32, OPC_OP)
        }
        Lw { rd, rs1, imm } => i_type(imm, rs1 as u32, 0b010, rd as u32, OPC_LOAD),
        Lbu { rd, rs1, imm } => i_type(imm, rs1 as u32, 0b100, rd as u32, OPC_LOAD),
        Sw { rs2, rs1, imm } => s_type(imm, rs2 as u32, rs1 as u32, 0b010, OPC_STORE),
        Sb { rs2, rs1, imm } => s_type(imm, rs2 as u32, rs1 as u32, 0b000, OPC_STORE),
        Branch { cond, rs1, rs2, off } => b_type(off, rs2 as u32, rs1 as u32, branch_funct3(cond)),
        Jal { rd, off } => j_type(off, rd as u32),
        Jalr { rd, rs1, imm } => i_type(imm, rs1 as u32, 0b000, rd as u32, OPC_JALR),
        Halt => OPC_SYSTEM, // ecall
        Vsetvli { rd, rs1, vtype } => {
            i_type(vtype.zimm() as i32, rs1 as u32, 0b111, rd as u32, OPC_V)
        }
        Vsetivli { rd, uimm, vtype } => {
            (0b11 << 30)
                | ((vtype.zimm() & 0x3ff) << 20)
                | ((uimm as u32) << 15)
                | (0b111 << 12)
                | ((rd as u32) << 7)
                | OPC_V
        }
        Vle { eew, vd, rs1 } => {
            (1 << 25)
                | ((rs1 as u32) << 15)
                | (vl_width_bits(eew) << 12)
                | ((vd as u32) << 7)
                | OPC_VL
        }
        Vse { eew, vs3, rs1 } => {
            (1 << 25)
                | ((rs1 as u32) << 15)
                | (vl_width_bits(eew) << 12)
                | ((vs3 as u32) << 7)
                | OPC_VS
        }
        Vlse { eew, vd, rs1, rs2 } => {
            (0b10 << 26)
                | (1 << 25)
                | ((rs2 as u32) << 20)
                | ((rs1 as u32) << 15)
                | (vl_width_bits(eew) << 12)
                | ((vd as u32) << 7)
                | OPC_VL
        }
        VaddVV { vd, vs1, vs2 } => v_arith(0b000000, vs2 as u32, vs1 as u32, OPIVV, vd as u32),
        VaddVX { vd, rs1, vs2 } => v_arith(0b000000, vs2 as u32, rs1 as u32, OPIVX, vd as u32),
        VaddVI { vd, imm, vs2 } => {
            v_arith(0b000000, vs2 as u32, (imm as u32) & 0x1f, OPIVI, vd as u32)
        }
        VsubVV { vd, vs1, vs2 } => v_arith(0b000010, vs2 as u32, vs1 as u32, OPIVV, vd as u32),
        VmulVV { vd, vs1, vs2 } => v_arith(0b100101, vs2 as u32, vs1 as u32, OPMVV, vd as u32),
        VmaccVV { vd, vs1, vs2 } => v_arith(0b101101, vs2 as u32, vs1 as u32, OPMVV, vd as u32),
        VredsumVS { vd, vs1, vs2 } => v_arith(0b000000, vs2 as u32, vs1 as u32, OPMVV, vd as u32),
        VmvVI { vd, imm } => v_arith(0b010111, 0, (imm as u32) & 0x1f, OPIVI, vd as u32),
        VmvVX { vd, rs1 } => v_arith(0b010111, 0, rs1 as u32, OPIVX, vd as u32),
        VmvXS { rd, vs2 } => v_arith(0b010000, vs2 as u32, 0b00000, OPMVV, rd as u32),
        VsextVf4 { vd, vs2 } => v_arith(0b010010, vs2 as u32, 0b00101, OPMVV, vd as u32),
        VmaxVX { vd, rs1, vs2 } => v_arith(0b000111, vs2 as u32, rs1 as u32, OPIVX, vd as u32),
        VminVX { vd, rs1, vs2 } => v_arith(0b000101, vs2 as u32, rs1 as u32, OPIVX, vd as u32),
        VsraVI { vd, imm, vs2 } => v_arith(0b101001, vs2 as u32, imm as u32, OPIVI, vd as u32),
        VsllVI { vd, imm, vs2 } => v_arith(0b100101, vs2 as u32, imm as u32, OPIVI, vd as u32),
        VsrlVI { vd, imm, vs2 } => v_arith(0b101000, vs2 as u32, imm as u32, OPIVI, vd as u32),
        VandVI { vd, imm, vs2 } => {
            v_arith(0b001001, vs2 as u32, (imm as u32) & 0x1f, OPIVI, vd as u32)
        }
        VandVV { vd, vs1, vs2 } => v_arith(0b001001, vs2 as u32, vs1 as u32, OPIVV, vd as u32),
        VorVV { vd, vs1, vs2 } => v_arith(0b001010, vs2 as u32, vs1 as u32, OPIVV, vd as u32),
        VxorVV { vd, vs1, vs2 } => v_arith(0b001011, vs2 as u32, vs1 as u32, OPIVV, vd as u32),
        VslidedownVI { vd, imm, vs2 } => {
            v_arith(0b001111, vs2 as u32, imm as u32, OPIVI, vd as u32)
        }
        VslideupVI { vd, imm, vs2 } => v_arith(0b001110, vs2 as u32, imm as u32, OPIVI, vd as u32),

        DlI { nvec, mask, vs1, width, sec } => {
            debug_assert!((1..=4).contains(&nvec) && mask < 16 && sec < 4 && width < 4);
            ((nvec as u32 - 1) << 30)
                | ((mask as u32) << 25)
                | ((vs1 as u32) << 20)
                | ((width as u32) << 18)
                | ((sec as u32) << 15)
                | (F3_DLI << 12)
                | OPC_CUSTOM0
        }
        DlM { nvec, mask, vs1, width, sec, m_row } => {
            debug_assert!((1..=4).contains(&nvec) && mask < 16 && sec < 4 && m_row < 32);
            ((nvec as u32 - 1) << 30)
                | ((mask as u32) << 25)
                | ((vs1 as u32) << 20)
                | ((width as u32) << 18)
                | ((sec as u32) << 15)
                | (F3_DLM << 12)
                | ((m_row as u32) << 7)
                | OPC_CUSTOM0
        }
        DcP { sh, dh, m_row, vs1, width, vd } => {
            debug_assert!(m_row < 32 && width < 4);
            ((sh as u32) << 31)
                | ((dh as u32) << 30)
                | ((m_row as u32) << 25)
                | ((vs1 as u32) << 20)
                | ((width as u32) << 18)
                | (F3_DCP << 12)
                | ((vd as u32) << 7)
                | OPC_CUSTOM0
        }
        DcF { sh, dh, m_row, vs1, width, bidx, vd } => {
            debug_assert!(m_row < 32 && width < 4 && bidx < 8);
            ((sh as u32) << 31)
                | ((dh as u32) << 30)
                | ((m_row as u32) << 25)
                | ((vs1 as u32) << 20)
                | ((width as u32) << 18)
                | ((bidx as u32) << 15)
                | (F3_DCF << 12)
                | ((vd as u32) << 7)
                | OPC_CUSTOM0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::VType;

    #[test]
    fn custom0_opcode_is_reserved_space() {
        // custom-0 must not collide with any standard major opcode we use.
        for opc in [OPC_LUI, OPC_OP, OPC_OP_IMM, OPC_V, OPC_VL, OPC_VS, OPC_LOAD, OPC_STORE] {
            assert_ne!(OPC_CUSTOM0, opc);
        }
        let w = encode(&Instr::DlI { nvec: 4, mask: 0xf, vs1: 8, width: 0, sec: 3 });
        assert_eq!(w & 0x7f, OPC_CUSTOM0);
    }

    #[test]
    fn dcf_fields_land_where_documented() {
        let w = encode(&Instr::DcF {
            sh: true,
            dh: false,
            m_row: 0b10101,
            vs1: 0b00111,
            width: 2,
            bidx: 5,
            vd: 0b11001,
        });
        assert_eq!(w >> 31, 1); // sh
        assert_eq!((w >> 30) & 1, 0); // dh
        assert_eq!((w >> 25) & 0x1f, 0b10101); // m_row
        assert_eq!((w >> 20) & 0x1f, 0b00111); // vs1
        assert_eq!((w >> 18) & 0x3, 2); // width (precision)
        assert_eq!((w >> 15) & 0x7, 5); // bidx
        assert_eq!((w >> 12) & 0x7, F3_DCF);
        assert_eq!((w >> 7) & 0x1f, 0b11001); // vd
    }

    #[test]
    fn standard_encodings_spot_checks() {
        // addi x1, x2, -3  => 0xffd10093
        assert_eq!(
            encode(&Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 2, imm: -3 }),
            0xffd1_0093
        );
        // add x3, x4, x5 => 0x005201b3
        assert_eq!(encode(&Instr::Op { op: AluOp::Add, rd: 3, rs1: 4, rs2: 5 }), 0x0052_01b3);
        // lw x6, 16(x7) => 0x0103a303
        assert_eq!(encode(&Instr::Lw { rd: 6, rs1: 7, imm: 16 }), 0x0103_a303);
        // ecall
        assert_eq!(encode(&Instr::Halt), 0x0000_0073);
        // vsetvli x1, x2, e32,m1 => zimm=0b010000
        let w = encode(&Instr::Vsetvli { rd: 1, rs1: 2, vtype: VType::new(32, 1) });
        assert_eq!(w & 0x7f, OPC_V);
        assert_eq!((w >> 12) & 0x7, 0b111);
        assert_eq!(w >> 20, 0b010000);
    }
}
