//! Instruction decoding — the exact inverse of [`super::encode`].
//!
//! Decoding is total over the words `encode` can produce and returns
//! [`DecodeError`] for anything else, so `decode(encode(i)) == Ok(i)` is a
//! property-tested invariant (see `rust/tests/prop_isa.rs`).

use super::encode::*;
use super::{AluOp, BranchCond, Instr, VType};

/// Decoding failure, carrying the offending word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    UnknownOpcode(u32),
    UnknownFunct(u32),
    BadVType(u32),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::UnknownOpcode(w) => write!(f, "unknown opcode in {w:#010x}"),
            DecodeError::UnknownFunct(w) => write!(f, "unknown funct fields in {w:#010x}"),
            DecodeError::BadVType(w) => write!(f, "unsupported vtype in {w:#010x}"),
        }
    }
}

impl std::error::Error for DecodeError {}

#[inline]
fn rd(w: u32) -> u8 {
    ((w >> 7) & 0x1f) as u8
}
#[inline]
fn rs1(w: u32) -> u8 {
    ((w >> 15) & 0x1f) as u8
}
#[inline]
fn rs2(w: u32) -> u8 {
    ((w >> 20) & 0x1f) as u8
}
#[inline]
fn funct3(w: u32) -> u32 {
    (w >> 12) & 0x7
}
#[inline]
fn funct7(w: u32) -> u32 {
    w >> 25
}
#[inline]
fn i_imm(w: u32) -> i32 {
    (w as i32) >> 20
}
#[inline]
fn s_imm(w: u32) -> i32 {
    (((w as i32) >> 25) << 5) | (((w >> 7) & 0x1f) as i32)
}
#[inline]
fn b_imm(w: u32) -> i32 {
    let sign = (w as i32) >> 31; // bit 12 replicated
    (sign << 12)
        | ((((w >> 7) & 1) as i32) << 11)
        | ((((w >> 25) & 0x3f) as i32) << 5)
        | ((((w >> 8) & 0xf) as i32) << 1)
}
#[inline]
fn j_imm(w: u32) -> i32 {
    let sign = (w as i32) >> 31;
    (sign << 20)
        | ((((w >> 12) & 0xff) as i32) << 12)
        | ((((w >> 20) & 1) as i32) << 11)
        | ((((w >> 21) & 0x3ff) as i32) << 1)
}
/// Sign-extend a 5-bit field (vector simm5).
#[inline]
fn simm5(v: u32) -> i8 {
    ((v as i8) << 3) >> 3
}

fn decode_eew(width: u32, w: u32) -> Result<u8, DecodeError> {
    match width {
        0b000 => Ok(8),
        0b101 => Ok(16),
        0b110 => Ok(32),
        _ => Err(DecodeError::UnknownFunct(w)),
    }
}

fn decode_opv(w: u32) -> Result<Instr, DecodeError> {
    let f3 = funct3(w);
    if f3 == 0b111 {
        // vsetvli / vsetivli
        return if w >> 30 == 0b11 {
            let vt = VType::from_zimm((w >> 20) & 0x3ff).ok_or(DecodeError::BadVType(w))?;
            Ok(Instr::Vsetivli { rd: rd(w), uimm: rs1(w), vtype: vt })
        } else if w >> 31 == 0 {
            let vt = VType::from_zimm((w >> 20) & 0x7ff).ok_or(DecodeError::BadVType(w))?;
            Ok(Instr::Vsetvli { rd: rd(w), rs1: rs1(w), vtype: vt })
        } else {
            Err(DecodeError::UnknownFunct(w))
        };
    }
    let funct6 = w >> 26;
    let vd = rd(w);
    let vs2 = rs2(w);
    let src = rs1(w); // vs1 / rs1 / simm5 slot
    match (funct6, f3) {
        (0b000000, OPIVV) => Ok(Instr::VaddVV { vd, vs1: src, vs2 }),
        (0b000000, OPIVX) => Ok(Instr::VaddVX { vd, rs1: src, vs2 }),
        (0b000000, OPIVI) => Ok(Instr::VaddVI { vd, imm: simm5(src as u32), vs2 }),
        (0b000010, OPIVV) => Ok(Instr::VsubVV { vd, vs1: src, vs2 }),
        (0b100101, OPMVV) => Ok(Instr::VmulVV { vd, vs1: src, vs2 }),
        (0b101101, OPMVV) => Ok(Instr::VmaccVV { vd, vs1: src, vs2 }),
        (0b000000, OPMVV) => Ok(Instr::VredsumVS { vd, vs1: src, vs2 }),
        (0b010111, OPIVI) => Ok(Instr::VmvVI { vd, imm: simm5(src as u32) }),
        (0b010111, OPIVX) => Ok(Instr::VmvVX { vd, rs1: src }),
        (0b010000, OPMVV) if src == 0 => Ok(Instr::VmvXS { rd: vd, vs2 }),
        (0b010010, OPMVV) if src == 0b00101 => Ok(Instr::VsextVf4 { vd, vs2 }),
        (0b000111, OPIVX) => Ok(Instr::VmaxVX { vd, rs1: src, vs2 }),
        (0b000101, OPIVX) => Ok(Instr::VminVX { vd, rs1: src, vs2 }),
        (0b101001, OPIVI) => Ok(Instr::VsraVI { vd, imm: src, vs2 }),
        (0b100101, OPIVI) => Ok(Instr::VsllVI { vd, imm: src, vs2 }),
        (0b101000, OPIVI) => Ok(Instr::VsrlVI { vd, imm: src, vs2 }),
        (0b001001, OPIVI) => Ok(Instr::VandVI { vd, imm: simm5(src as u32), vs2 }),
        (0b001001, OPIVV) => Ok(Instr::VandVV { vd, vs1: src, vs2 }),
        (0b001010, OPIVV) => Ok(Instr::VorVV { vd, vs1: src, vs2 }),
        (0b001011, OPIVV) => Ok(Instr::VxorVV { vd, vs1: src, vs2 }),
        (0b001111, OPIVI) => Ok(Instr::VslidedownVI { vd, imm: src, vs2 }),
        (0b001110, OPIVI) => Ok(Instr::VslideupVI { vd, imm: src, vs2 }),
        _ => Err(DecodeError::UnknownFunct(w)),
    }
}

fn decode_custom0(w: u32) -> Result<Instr, DecodeError> {
    let f3 = funct3(w);
    match f3 {
        F3_DLI | F3_DLM => {
            let nvec = ((w >> 30) & 0x3) as u8 + 1;
            let mask = ((w >> 25) & 0xf) as u8;
            let vs1 = rs2(w); // [24:20]
            let width = ((w >> 18) & 0x3) as u8;
            let sec = ((w >> 15) & 0x3) as u8;
            if f3 == F3_DLI {
                Ok(Instr::DlI { nvec, mask, vs1, width, sec })
            } else {
                Ok(Instr::DlM { nvec, mask, vs1, width, sec, m_row: rd(w) })
            }
        }
        F3_DCP | F3_DCF => {
            let sh = (w >> 31) == 1;
            let dh = ((w >> 30) & 1) == 1;
            let m_row = ((w >> 25) & 0x1f) as u8;
            let vs1 = rs2(w);
            let width = ((w >> 18) & 0x3) as u8;
            let vd = rd(w);
            if f3 == F3_DCP {
                Ok(Instr::DcP { sh, dh, m_row, vs1, width, vd })
            } else {
                Ok(Instr::DcF { sh, dh, m_row, vs1, width, bidx: ((w >> 15) & 0x7) as u8, vd })
            }
        }
        _ => Err(DecodeError::UnknownFunct(w)),
    }
}

/// Decode a 32-bit machine word.
pub fn decode(w: u32) -> Result<Instr, DecodeError> {
    match w & 0x7f {
        OPC_LUI => Ok(Instr::Lui { rd: rd(w), imm: (w >> 12) as i32 }),
        OPC_AUIPC => Ok(Instr::Auipc { rd: rd(w), imm: (w >> 12) as i32 }),
        OPC_OP_IMM => {
            let op = match funct3(w) {
                0b000 => AluOp::Add,
                0b001 => AluOp::Sll,
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => {
                    if funct7(w) == 0b0100000 {
                        AluOp::Sra
                    } else {
                        AluOp::Srl
                    }
                }
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => unreachable!(),
            };
            let imm = if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                (rs2(w)) as i32
            } else {
                i_imm(w)
            };
            Ok(Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm })
        }
        OPC_OP => {
            let op = match (funct7(w), funct3(w)) {
                (0b0000001, 0b000) => AluOp::Mul,
                (0b0100000, 0b000) => AluOp::Sub,
                (0b0000000, 0b000) => AluOp::Add,
                (0b0000000, 0b001) => AluOp::Sll,
                (0b0000000, 0b010) => AluOp::Slt,
                (0b0000000, 0b011) => AluOp::Sltu,
                (0b0000000, 0b100) => AluOp::Xor,
                (0b0100000, 0b101) => AluOp::Sra,
                (0b0000000, 0b101) => AluOp::Srl,
                (0b0000000, 0b110) => AluOp::Or,
                (0b0000000, 0b111) => AluOp::And,
                _ => return Err(DecodeError::UnknownFunct(w)),
            };
            Ok(Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) })
        }
        OPC_LOAD => match funct3(w) {
            0b010 => Ok(Instr::Lw { rd: rd(w), rs1: rs1(w), imm: i_imm(w) }),
            0b100 => Ok(Instr::Lbu { rd: rd(w), rs1: rs1(w), imm: i_imm(w) }),
            _ => Err(DecodeError::UnknownFunct(w)),
        },
        OPC_STORE => match funct3(w) {
            0b010 => Ok(Instr::Sw { rs2: rs2(w), rs1: rs1(w), imm: s_imm(w) }),
            0b000 => Ok(Instr::Sb { rs2: rs2(w), rs1: rs1(w), imm: s_imm(w) }),
            _ => Err(DecodeError::UnknownFunct(w)),
        },
        OPC_BRANCH => {
            let cond = match funct3(w) {
                0b000 => BranchCond::Eq,
                0b001 => BranchCond::Ne,
                0b100 => BranchCond::Lt,
                0b101 => BranchCond::Ge,
                0b110 => BranchCond::Ltu,
                0b111 => BranchCond::Geu,
                _ => return Err(DecodeError::UnknownFunct(w)),
            };
            Ok(Instr::Branch { cond, rs1: rs1(w), rs2: rs2(w), off: b_imm(w) })
        }
        OPC_JAL => Ok(Instr::Jal { rd: rd(w), off: j_imm(w) }),
        OPC_JALR => Ok(Instr::Jalr { rd: rd(w), rs1: rs1(w), imm: i_imm(w) }),
        OPC_SYSTEM => Ok(Instr::Halt),
        OPC_V => decode_opv(w),
        OPC_VL => {
            let eew = decode_eew(funct3(w), w)?;
            match (w >> 26) & 0x3 {
                0b00 => Ok(Instr::Vle { eew, vd: rd(w), rs1: rs1(w) }),
                0b10 => Ok(Instr::Vlse { eew, vd: rd(w), rs1: rs1(w), rs2: rs2(w) }),
                _ => Err(DecodeError::UnknownFunct(w)),
            }
        }
        OPC_VS => {
            let eew = decode_eew(funct3(w), w)?;
            Ok(Instr::Vse { eew, vs3: rd(w), rs1: rs1(w) })
        }
        OPC_CUSTOM0 => decode_custom0(w),
        _ => Err(DecodeError::UnknownOpcode(w)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::VType;

    fn rt(i: Instr) {
        assert_eq!(decode(encode(&i)), Ok(i), "round-trip failed for {i}");
    }

    #[test]
    fn roundtrip_scalar() {
        rt(Instr::Lui { rd: 5, imm: 0xfffff });
        rt(Instr::Auipc { rd: 1, imm: 0x12345 });
        rt(Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 2, imm: -2048 });
        rt(Instr::OpImm { op: AluOp::Sll, rd: 1, rs1: 2, imm: 31 });
        rt(Instr::OpImm { op: AluOp::Sra, rd: 1, rs1: 2, imm: 7 });
        rt(Instr::Op { op: AluOp::Sub, rd: 3, rs1: 4, rs2: 5 });
        rt(Instr::Op { op: AluOp::Mul, rd: 3, rs1: 4, rs2: 5 });
        rt(Instr::Lw { rd: 6, rs1: 7, imm: -4 });
        rt(Instr::Lbu { rd: 6, rs1: 7, imm: 2047 });
        rt(Instr::Sw { rs2: 8, rs1: 9, imm: -2048 });
        rt(Instr::Sb { rs2: 8, rs1: 9, imm: 100 });
        rt(Instr::Branch { cond: BranchCond::Ne, rs1: 1, rs2: 2, off: -4096 });
        rt(Instr::Branch { cond: BranchCond::Geu, rs1: 1, rs2: 2, off: 4094 });
        rt(Instr::Jal { rd: 1, off: -1048576 });
        rt(Instr::Jalr { rd: 1, rs1: 2, imm: 16 });
        rt(Instr::Halt);
    }

    #[test]
    fn roundtrip_vector() {
        rt(Instr::Vsetvli { rd: 1, rs1: 2, vtype: VType::new(8, 4) });
        rt(Instr::Vsetivli { rd: 1, uimm: 16, vtype: VType::new(32, 2) });
        rt(Instr::Vle { eew: 8, vd: 3, rs1: 4 });
        rt(Instr::Vle { eew: 32, vd: 3, rs1: 4 });
        rt(Instr::Vse { eew: 16, vs3: 3, rs1: 4 });
        rt(Instr::Vlse { eew: 8, vd: 3, rs1: 4, rs2: 5 });
        rt(Instr::VaddVV { vd: 1, vs1: 2, vs2: 3 });
        rt(Instr::VaddVI { vd: 1, imm: -16, vs2: 3 });
        rt(Instr::VmaccVV { vd: 1, vs1: 2, vs2: 3 });
        rt(Instr::VredsumVS { vd: 1, vs1: 2, vs2: 3 });
        rt(Instr::VsextVf4 { vd: 4, vs2: 8 });
        rt(Instr::VmvXS { rd: 10, vs2: 8 });
        rt(Instr::VmaxVX { vd: 1, rs1: 0, vs2: 3 });
        rt(Instr::VsraVI { vd: 1, imm: 31, vs2: 3 });
        rt(Instr::VslidedownVI { vd: 1, imm: 4, vs2: 3 });
    }

    #[test]
    fn roundtrip_custom() {
        rt(Instr::DlI { nvec: 1, mask: 0x1, vs1: 31, width: 3, sec: 0 });
        rt(Instr::DlI { nvec: 4, mask: 0xf, vs1: 0, width: 0, sec: 3 });
        rt(Instr::DlM { nvec: 2, mask: 0b11, vs1: 16, width: 1, sec: 2, m_row: 31 });
        rt(Instr::DcP { sh: true, dh: false, m_row: 17, vs1: 3, width: 0, vd: 29 });
        rt(Instr::DcF { sh: false, dh: true, m_row: 31, vs1: 3, width: 2, bidx: 7, vd: 1 });
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_007f).is_err()); // unknown major opcode
    }
}
