//! A small two-pass assembler for the modelled ISA.
//!
//! Supports labels (`loop:`), comments (`# ...` / `; ...`), the scalar and
//! vector mnemonics produced by [`Instr`]'s `Display` impl, and the four
//! custom DIMC mnemonics with keyword operands, e.g.:
//!
//! ```text
//! dl.i  v8,  nvec=4, mask=0b1111, sec=0
//! dl.m  v8,  nvec=4, mask=0b1111, sec=1, row=7
//! dc.p  v4.0, v4.1, row=7, w=0
//! dc.f  v4.0[3], v4.1, row=7, w=0
//! li    x5, 1024          # pseudo: expands to lui+addi or addi
//! ```
//!
//! Assembled programs run directly on the DIMC-enhanced core model — the
//! snippet below performs the paper's whole load/compute/write-back
//! motif: a kernel row image into DIMC memory (`dl.m`), an activation
//! patch into the input buffer (`dl.i`), and one in-memory MAC with
//! ReLU + requantization packing the result nibble (`dc.f`):
//!
//! ```
//! use dimc_rvv::arch::Arch;
//! use dimc_rvv::isa::asm::assemble;
//! use dimc_rvv::pipeline::Core;
//!
//! let prog = assemble(
//!     "
//!     dl.m v8,  nvec=4, mask=0b1111, sec=0, row=3   # kernel -> DIMC row 3
//!     dl.i v12, nvec=4, mask=0b1111, sec=0          # patch  -> input buffer
//!     dc.f v4.0[0], v4.1, row=3, w=0                # MAC + ReLU + requant
//!     ecall
//!     ",
//! )
//! .unwrap();
//! assert_eq!(prog.len(), 4);
//!
//! let mut core = Core::new(Arch::default());
//! let stats = core.run(&prog, 10_000).unwrap();
//! assert_eq!(stats.instret, 4, "all four instructions retired");
//! assert!(stats.cycles >= 4, "a {}-cycle run is too good to be true", stats.cycles);
//! ```

use super::{AluOp, BranchCond, Instr, VType};
use std::collections::HashMap;

/// Assembly error with 1-based line number.
#[derive(Debug)]
pub struct AsmError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for AsmError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, AsmError> {
    Err(AsmError { line, msg: msg.into() })
}

fn parse_int(s: &str, line: usize) -> Result<i64, AsmError> {
    let s = s.trim();
    let (neg, s) = match s.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, s),
    };
    let v = if let Some(h) = s.strip_prefix("0x") {
        i64::from_str_radix(h, 16)
    } else if let Some(b) = s.strip_prefix("0b") {
        i64::from_str_radix(b, 2)
    } else {
        s.parse()
    };
    match v {
        Ok(v) => Ok(if neg { -v } else { v }),
        Err(_) => err(line, format!("bad integer `{s}`")),
    }
}

fn xreg(s: &str, line: usize) -> Result<u8, AsmError> {
    let s = s.trim();
    let named = [
        ("zero", 0u8),
        ("ra", 1),
        ("sp", 2),
        ("t0", 5),
        ("t1", 6),
        ("t2", 7),
        ("a0", 10),
        ("a1", 11),
        ("a2", 12),
        ("a3", 13),
        ("a4", 14),
        ("a5", 15),
    ];
    for (n, i) in named {
        if s == n {
            return Ok(i);
        }
    }
    if let Some(n) = s.strip_prefix('x') {
        if let Ok(v) = n.parse::<u8>() {
            if v < 32 {
                return Ok(v);
            }
        }
    }
    err(line, format!("bad x-register `{s}`"))
}

fn vreg(s: &str, line: usize) -> Result<u8, AsmError> {
    if let Some(n) = s.trim().strip_prefix('v') {
        if let Ok(v) = n.parse::<u8>() {
            if v < 32 {
                return Ok(v);
            }
        }
    }
    err(line, format!("bad v-register `{s}`"))
}

/// `v4.1` -> (vreg, half); `v4.0[3]` -> (vreg, half, nibble).
fn vreg_half(s: &str, line: usize) -> Result<(u8, bool, Option<u8>), AsmError> {
    let s = s.trim();
    let (core, bidx) = match s.split_once('[') {
        Some((c, rest)) => {
            let idx = rest.strip_suffix(']').ok_or(AsmError {
                line,
                msg: format!("missing `]` in `{s}`"),
            })?;
            (c, Some(parse_int(idx, line)? as u8))
        }
        None => (s, None),
    };
    let (r, h) = core.split_once('.').ok_or(AsmError {
        line,
        msg: format!("expected vREG.half in `{s}`"),
    })?;
    Ok((vreg(r, line)?, parse_int(h, line)? != 0, bidx))
}

fn kwargs(ops: &[&str], line: usize) -> Result<HashMap<String, i64>, AsmError> {
    let mut m = HashMap::new();
    for o in ops {
        let (k, v) = o.split_once('=').ok_or(AsmError {
            line,
            msg: format!("expected key=value, got `{o}`"),
        })?;
        m.insert(k.trim().to_string(), parse_int(v, line)?);
    }
    Ok(m)
}

/// `16(x7)` -> (imm, reg); also accepts `(x7)` as 0 offset.
fn mem_operand(s: &str, line: usize) -> Result<(i32, u8), AsmError> {
    let s = s.trim();
    let open = s.find('(').ok_or(AsmError { line, msg: format!("expected imm(reg): `{s}`") })?;
    let close = s.rfind(')').ok_or(AsmError { line, msg: format!("missing `)`: `{s}`") })?;
    let imm = if open == 0 { 0 } else { parse_int(&s[..open], line)? as i32 };
    Ok((imm, xreg(&s[open + 1..close], line)?))
}

fn parse_vtype(ops: &[&str], line: usize) -> Result<VType, AsmError> {
    // e8,m4 style: passed through as two trailing operands
    let mut sew = None;
    let mut lmul = None;
    for o in ops {
        let o = o.trim();
        if let Some(e) = o.strip_prefix('e') {
            sew = Some(parse_int(e, line)? as u16);
        } else if let Some(m) = o.strip_prefix('m') {
            lmul = Some(parse_int(m, line)? as u8);
        }
    }
    match (sew, lmul) {
        (Some(s), Some(l)) if matches!(s, 8 | 16 | 32) && matches!(l, 1 | 2 | 4 | 8) => {
            Ok(VType::new(s, l))
        }
        _ => err(line, "expected eSEW,mLMUL"),
    }
}

/// Assemble a program. Returns the instruction sequence; labels resolve to
/// byte offsets (4 bytes per instruction).
pub fn assemble(src: &str) -> Result<Vec<Instr>, AsmError> {
    // Pass 1: collect labels.
    let mut labels: HashMap<String, i64> = HashMap::new();
    let mut pc = 0i64;
    let mut lines: Vec<(usize, String)> = Vec::new();
    for (ln, raw) in src.lines().enumerate() {
        let line = ln + 1;
        let code = raw.split(['#', ';']).next().unwrap_or("").trim().to_string();
        if code.is_empty() {
            continue;
        }
        if let Some(label) = code.strip_suffix(':') {
            labels.insert(label.trim().to_string(), pc);
            continue;
        }
        // `li` with a large immediate expands to two instructions.
        let big_li = code.starts_with("li ") && {
            let v = code[3..].split(',').nth(1).map(|s| parse_int(s, line)).transpose()?;
            v.map(|v| !(-2048..2048).contains(&v)).unwrap_or(false)
        };
        pc += if big_li { 8 } else { 4 };
        lines.push((line, code));
    }

    // Pass 2: emit.
    let mut out = Vec::new();
    let mut pc = 0i64;
    for (line, code) in &lines {
        let line = *line;
        let (mn, rest) = code.split_once(char::is_whitespace).unwrap_or((code.as_str(), ""));
        let ops: Vec<&str> = rest.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
        let need = |n: usize| -> Result<(), AsmError> {
            if ops.len() < n {
                err(line, format!("`{mn}` needs {n} operands, got {}", ops.len()))
            } else {
                Ok(())
            }
        };
        let branch_target = |s: &str| -> Result<i32, AsmError> {
            if let Some(&t) = labels.get(s) {
                Ok((t - pc) as i32)
            } else {
                Ok(parse_int(s, line)? as i32)
            }
        };
        let emitted: Vec<Instr> = match mn {
            "li" => {
                need(2)?;
                let rd = xreg(ops[0], line)?;
                let v = parse_int(ops[1], line)? as i32;
                if (-2048..2048).contains(&v) {
                    vec![Instr::OpImm { op: AluOp::Add, rd, rs1: 0, imm: v }]
                } else {
                    // lui + addi with sign-adjustment of the low part.
                    let lo = (v << 20) >> 20;
                    let hi = (v.wrapping_sub(lo)) >> 12;
                    vec![
                        Instr::Lui { rd, imm: hi & 0xfffff },
                        Instr::OpImm { op: AluOp::Add, rd, rs1: rd, imm: lo },
                    ]
                }
            }
            "mv" => {
                need(2)?;
                vec![Instr::OpImm {
                    op: AluOp::Add,
                    rd: xreg(ops[0], line)?,
                    rs1: xreg(ops[1], line)?,
                    imm: 0,
                }]
            }
            "nop" => vec![Instr::OpImm { op: AluOp::Add, rd: 0, rs1: 0, imm: 0 }],
            "addi" | "slli" | "srli" | "srai" | "andi" | "ori" | "xori" => {
                need(3)?;
                let op = match mn {
                    "addi" => AluOp::Add,
                    "slli" => AluOp::Sll,
                    "srli" => AluOp::Srl,
                    "srai" => AluOp::Sra,
                    "andi" => AluOp::And,
                    "ori" => AluOp::Or,
                    _ => AluOp::Xor,
                };
                vec![Instr::OpImm {
                    op,
                    rd: xreg(ops[0], line)?,
                    rs1: xreg(ops[1], line)?,
                    imm: parse_int(ops[2], line)? as i32,
                }]
            }
            "add" | "sub" | "mul" | "and" | "or" | "xor" | "sll" | "srl" | "sra" => {
                need(3)?;
                let op = match mn {
                    "add" => AluOp::Add,
                    "sub" => AluOp::Sub,
                    "mul" => AluOp::Mul,
                    "and" => AluOp::And,
                    "or" => AluOp::Or,
                    "xor" => AluOp::Xor,
                    "sll" => AluOp::Sll,
                    "srl" => AluOp::Srl,
                    _ => AluOp::Sra,
                };
                vec![Instr::Op {
                    op,
                    rd: xreg(ops[0], line)?,
                    rs1: xreg(ops[1], line)?,
                    rs2: xreg(ops[2], line)?,
                }]
            }
            "lw" | "lbu" => {
                need(2)?;
                let (imm, rs1) = mem_operand(ops[1], line)?;
                let rd = xreg(ops[0], line)?;
                vec![if mn == "lw" {
                    Instr::Lw { rd, rs1, imm }
                } else {
                    Instr::Lbu { rd, rs1, imm }
                }]
            }
            "sw" | "sb" => {
                need(2)?;
                let (imm, rs1) = mem_operand(ops[1], line)?;
                let rs2 = xreg(ops[0], line)?;
                vec![if mn == "sw" {
                    Instr::Sw { rs2, rs1, imm }
                } else {
                    Instr::Sb { rs2, rs1, imm }
                }]
            }
            "beq" | "bne" | "blt" | "bge" | "bltu" | "bgeu" => {
                need(3)?;
                let cond = match mn {
                    "beq" => BranchCond::Eq,
                    "bne" => BranchCond::Ne,
                    "blt" => BranchCond::Lt,
                    "bge" => BranchCond::Ge,
                    "bltu" => BranchCond::Ltu,
                    _ => BranchCond::Geu,
                };
                vec![Instr::Branch {
                    cond,
                    rs1: xreg(ops[0], line)?,
                    rs2: xreg(ops[1], line)?,
                    off: branch_target(ops[2])?,
                }]
            }
            "jal" => {
                need(1)?;
                let (rd, tgt) =
                    if ops.len() == 1 { (0u8, ops[0]) } else { (xreg(ops[0], line)?, ops[1]) };
                vec![Instr::Jal { rd, off: branch_target(tgt)? }]
            }
            "ecall" | "halt" => vec![Instr::Halt],
            "vsetvli" => {
                need(3)?;
                vec![Instr::Vsetvli {
                    rd: xreg(ops[0], line)?,
                    rs1: xreg(ops[1], line)?,
                    vtype: parse_vtype(&ops[2..], line)?,
                }]
            }
            "vle8.v" | "vle16.v" | "vle32.v" => {
                need(2)?;
                let eew: u8 = mn[3..mn.len() - 2].parse().unwrap();
                let (imm, rs1) = mem_operand(ops[1], line)?;
                if imm != 0 {
                    return err(line, "vector loads take (reg) with no offset");
                }
                vec![Instr::Vle { eew, vd: vreg(ops[0], line)?, rs1 }]
            }
            "vse8.v" | "vse16.v" | "vse32.v" => {
                need(2)?;
                let eew: u8 = mn[3..mn.len() - 2].parse().unwrap();
                let (imm, rs1) = mem_operand(ops[1], line)?;
                if imm != 0 {
                    return err(line, "vector stores take (reg) with no offset");
                }
                vec![Instr::Vse { eew, vs3: vreg(ops[0], line)?, rs1 }]
            }
            "vadd.vv" => {
                need(3)?;
                vec![Instr::VaddVV {
                    vd: vreg(ops[0], line)?,
                    vs2: vreg(ops[1], line)?,
                    vs1: vreg(ops[2], line)?,
                }]
            }
            "vadd.vi" => {
                need(3)?;
                vec![Instr::VaddVI {
                    vd: vreg(ops[0], line)?,
                    vs2: vreg(ops[1], line)?,
                    imm: parse_int(ops[2], line)? as i8,
                }]
            }
            "vmacc.vv" => {
                need(3)?;
                vec![Instr::VmaccVV {
                    vd: vreg(ops[0], line)?,
                    vs1: vreg(ops[1], line)?,
                    vs2: vreg(ops[2], line)?,
                }]
            }
            "vredsum.vs" => {
                need(3)?;
                vec![Instr::VredsumVS {
                    vd: vreg(ops[0], line)?,
                    vs2: vreg(ops[1], line)?,
                    vs1: vreg(ops[2], line)?,
                }]
            }
            "vsext.vf4" => {
                need(2)?;
                vec![Instr::VsextVf4 { vd: vreg(ops[0], line)?, vs2: vreg(ops[1], line)? }]
            }
            "vmv.v.i" => {
                need(2)?;
                vec![Instr::VmvVI { vd: vreg(ops[0], line)?, imm: parse_int(ops[1], line)? as i8 }]
            }
            "vmv.v.x" => {
                need(2)?;
                vec![Instr::VmvVX { vd: vreg(ops[0], line)?, rs1: xreg(ops[1], line)? }]
            }
            "vmv.x.s" => {
                need(2)?;
                vec![Instr::VmvXS { rd: xreg(ops[0], line)?, vs2: vreg(ops[1], line)? }]
            }
            "vmax.vx" => {
                need(3)?;
                vec![Instr::VmaxVX {
                    vd: vreg(ops[0], line)?,
                    vs2: vreg(ops[1], line)?,
                    rs1: xreg(ops[2], line)?,
                }]
            }
            "dl.i" => {
                need(2)?;
                let vs1 = vreg(ops[0], line)?;
                let kw = kwargs(&ops[1..], line)?;
                vec![Instr::DlI {
                    nvec: *kw.get("nvec").unwrap_or(&4) as u8,
                    mask: *kw.get("mask").unwrap_or(&0xf) as u8,
                    vs1,
                    width: *kw.get("w").unwrap_or(&0) as u8,
                    sec: *kw.get("sec").unwrap_or(&0) as u8,
                }]
            }
            "dl.m" => {
                need(2)?;
                let vs1 = vreg(ops[0], line)?;
                let kw = kwargs(&ops[1..], line)?;
                vec![Instr::DlM {
                    nvec: *kw.get("nvec").unwrap_or(&4) as u8,
                    mask: *kw.get("mask").unwrap_or(&0xf) as u8,
                    vs1,
                    width: *kw.get("w").unwrap_or(&0) as u8,
                    sec: *kw.get("sec").unwrap_or(&0) as u8,
                    m_row: *kw.get("row").ok_or(AsmError {
                        line,
                        msg: "dl.m needs row=".into(),
                    })? as u8,
                }]
            }
            "dc.p" => {
                need(3)?;
                let (vd, dh, _) = vreg_half(ops[0], line)?;
                let (vs1, sh, _) = vreg_half(ops[1], line)?;
                let kw = kwargs(&ops[2..], line)?;
                vec![Instr::DcP {
                    sh,
                    dh,
                    m_row: *kw.get("row").ok_or(AsmError {
                        line,
                        msg: "dc.p needs row=".into(),
                    })? as u8,
                    vs1,
                    width: *kw.get("w").unwrap_or(&0) as u8,
                    vd,
                }]
            }
            "dc.f" => {
                need(3)?;
                let (vd, dh, bidx) = vreg_half(ops[0], line)?;
                let (vs1, sh, _) = vreg_half(ops[1], line)?;
                let kw = kwargs(&ops[2..], line)?;
                vec![Instr::DcF {
                    sh,
                    dh,
                    m_row: *kw.get("row").ok_or(AsmError {
                        line,
                        msg: "dc.f needs row=".into(),
                    })? as u8,
                    vs1,
                    width: *kw.get("w").unwrap_or(&0) as u8,
                    bidx: bidx.unwrap_or(0),
                    vd,
                }]
            }
            _ => return err(line, format!("unknown mnemonic `{mn}`")),
        };
        pc += 4 * emitted.len() as i64;
        out.extend(emitted);
    }
    Ok(out)
}

/// Disassemble a slice of instructions to text (one per line).
pub fn disassemble(prog: &[Instr]) -> String {
    prog.iter().map(|i| i.to_string()).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_loop() {
        let prog = assemble(
            r"
            # tiny accumulation loop
            li   x5, 0
            li   x6, 8
        loop:
            addi x5, x5, 1
            bne  x5, x6, loop
            ecall
        ",
        )
        .unwrap();
        assert_eq!(prog.len(), 5);
        match prog[3] {
            Instr::Branch { cond: BranchCond::Ne, off, .. } => assert_eq!(off, -4),
            ref other => panic!("expected bne, got {other}"),
        }
    }

    #[test]
    fn assemble_custom() {
        let prog = assemble(
            r"
            dl.i v8, nvec=4, mask=0b1111, sec=2
            dl.m v12, nvec=2, mask=0b11, sec=0, row=7
            dc.p v4.1, v4.0, row=7, w=0
            dc.f v6.0[5], v4.1, row=8, w=0
        ",
        )
        .unwrap();
        assert_eq!(prog[0], Instr::DlI { nvec: 4, mask: 0xf, vs1: 8, width: 0, sec: 2 });
        assert_eq!(
            prog[1],
            Instr::DlM { nvec: 2, mask: 0b11, vs1: 12, width: 0, sec: 0, m_row: 7 }
        );
        assert_eq!(prog[2], Instr::DcP { sh: false, dh: true, m_row: 7, vs1: 4, width: 0, vd: 4 });
        assert_eq!(
            prog[3],
            Instr::DcF { sh: true, dh: false, m_row: 8, vs1: 4, width: 0, bidx: 5, vd: 6 }
        );
    }

    #[test]
    fn li_expansion() {
        let prog = assemble("li x5, 0x12345\necall").unwrap();
        assert_eq!(prog.len(), 3);
        // Verify the lui+addi pair reconstructs the constant.
        if let (Instr::Lui { imm: hi, .. }, Instr::OpImm { imm: lo, .. }) = (prog[0], prog[1]) {
            assert_eq!((hi << 12).wrapping_add(lo), 0x12345);
        } else {
            panic!("expected lui+addi");
        }
    }

    #[test]
    fn labels_account_for_li_size() {
        // A big li before the label must not skew branch offsets.
        let prog = assemble(
            r"
            li x5, 100000
            li x6, 1
        loop:
            addi x6, x6, 1
            bne x6, x5, loop
            ecall",
        )
        .unwrap();
        match prog[4] {
            Instr::Branch { off, .. } => assert_eq!(off, -4),
            ref other => panic!("expected branch, got {other}"),
        }
    }

    #[test]
    fn vector_mnemonics() {
        let prog = assemble(
            r"
            vsetvli x1, x2, e8, m4
            vle8.v v8, (x10)
            vsext.vf4 v16, v8
            vmacc.vv v24, v16, v20
            vse32.v v24, (x11)",
        )
        .unwrap();
        assert_eq!(prog.len(), 5);
        assert_eq!(prog[2], Instr::VsextVf4 { vd: 16, vs2: 8 });
    }
}
