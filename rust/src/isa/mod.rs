//! Instruction set: RV32IM scalar subset + Zve32x vector subset + the four
//! custom DIMC instructions of the paper (Section IV).
//!
//! The custom instructions live in the RISC-V *custom-0* opcode space
//! (0b0001011), exactly as the paper prescribes, with the bit-level layout
//! of Fig. 4 (see [`encode`] for the field map — the figure in the preprint
//! is partially garbled, so the precise bit positions used here are
//! documented as the normative layout of this reproduction).
//!
//! * `DL.I`  — load 64..256 bits from `nvec` consecutive VRF registers
//!   (valid-bit `mask`) into sector `sec` of the DIMC input buffer.
//! * `DL.M`  — same, into sector `sec` of DIMC memory row `m_row`.
//! * `DC.P`  — in-memory MAC of input buffer x row `m_row`; takes a 24-bit
//!   partial sum from half `sh` of `vs1`, writes the new 24-bit partial sum
//!   (padded to 32) to half `dh` of `vd`.
//! * `DC.F`  — as `DC.P` plus ReLU + requantization to 4/2/1 bits; the
//!   result nibble is packed into nibble `bidx` of half `dh` of `vd`.

pub mod encode;
pub mod decode;
pub mod asm;

use std::fmt;

/// Scalar ALU operation (register-register and register-immediate forms).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Srl,
    Sra,
    And,
    Or,
    Xor,
    Slt,
    Sltu,
    /// M extension multiply (register-register form only).
    Mul,
}

/// Branch comparison condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BranchCond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// Vector type configuration established by `vsetvli`.
///
/// Only the integer Zve32x subset is modelled: SEW in {8, 16, 32} and
/// integer LMUL in {1, 2, 4, 8}.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VType {
    /// Selected element width in bits.
    pub sew: u16,
    /// Register group multiplier.
    pub lmul: u8,
}

impl VType {
    pub fn new(sew: u16, lmul: u8) -> Self {
        debug_assert!(matches!(sew, 8 | 16 | 32));
        debug_assert!(matches!(lmul, 1 | 2 | 4 | 8));
        VType { sew, lmul }
    }

    /// VLMAX = LMUL * VLEN / SEW.
    pub fn vlmax(&self) -> u32 {
        self.lmul as u32 * crate::arch::VLEN / self.sew as u32
    }

    /// The 8-bit vtype immediate (vlmul[2:0], vsew[5:3]), tail/mask agnostic.
    pub fn zimm(&self) -> u32 {
        let vlmul = match self.lmul {
            1 => 0b000,
            2 => 0b001,
            4 => 0b010,
            8 => 0b011,
            _ => unreachable!(),
        };
        let vsew = match self.sew {
            8 => 0b000,
            16 => 0b001,
            32 => 0b010,
            _ => unreachable!(),
        };
        vlmul | (vsew << 3)
    }

    pub fn from_zimm(zimm: u32) -> Option<Self> {
        let lmul = match zimm & 0b111 {
            0b000 => 1,
            0b001 => 2,
            0b010 => 4,
            0b011 => 8,
            _ => return None,
        };
        let sew = match (zimm >> 3) & 0b111 {
            0b000 => 8,
            0b001 => 16,
            0b010 => 32,
            _ => return None,
        };
        Some(VType { sew, lmul })
    }
}

/// One decoded instruction. PC-relative offsets are in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    // ----- RV32I / M scalar subset -----
    Lui { rd: u8, imm: i32 },
    Auipc { rd: u8, imm: i32 },
    /// Register-immediate ALU (`Mul` is invalid here).
    OpImm { op: AluOp, rd: u8, rs1: u8, imm: i32 },
    /// Register-register ALU.
    Op { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
    Lw { rd: u8, rs1: u8, imm: i32 },
    Lbu { rd: u8, rs1: u8, imm: i32 },
    Sw { rs2: u8, rs1: u8, imm: i32 },
    Sb { rs2: u8, rs1: u8, imm: i32 },
    Branch { cond: BranchCond, rs1: u8, rs2: u8, off: i32 },
    Jal { rd: u8, off: i32 },
    Jalr { rd: u8, rs1: u8, imm: i32 },
    /// `ecall` — terminates simulation (the trace's exit convention).
    Halt,

    // ----- Zve32x vector subset -----
    Vsetvli { rd: u8, rs1: u8, vtype: VType },
    /// `vsetivli` with a 5-bit immediate AVL.
    Vsetivli { rd: u8, uimm: u8, vtype: VType },
    /// Unit-stride load, `eew` in {8, 16, 32}.
    Vle { eew: u8, vd: u8, rs1: u8 },
    /// Unit-stride store.
    Vse { eew: u8, vs3: u8, rs1: u8 },
    /// Strided load (byte stride in `rs2`).
    Vlse { eew: u8, vd: u8, rs1: u8, rs2: u8 },
    VaddVV { vd: u8, vs1: u8, vs2: u8 },
    VaddVX { vd: u8, rs1: u8, vs2: u8 },
    VaddVI { vd: u8, imm: i8, vs2: u8 },
    VsubVV { vd: u8, vs1: u8, vs2: u8 },
    VmulVV { vd: u8, vs1: u8, vs2: u8 },
    /// `vmacc.vv vd, vs1, vs2`: vd += vs1 * vs2.
    VmaccVV { vd: u8, vs1: u8, vs2: u8 },
    /// `vredsum.vs vd, vs2, vs1`: vd[0] = sum(vs2[*]) + vs1[0].
    VredsumVS { vd: u8, vs1: u8, vs2: u8 },
    VmvVI { vd: u8, imm: i8 },
    VmvVX { vd: u8, rs1: u8 },
    /// `vmv.x.s rd, vs2`: rd = vs2[0].
    VmvXS { rd: u8, vs2: u8 },
    /// Sign-extend quarter-width elements: SEW/4 -> SEW.
    VsextVf4 { vd: u8, vs2: u8 },
    VmaxVX { vd: u8, rs1: u8, vs2: u8 },
    VminVX { vd: u8, rs1: u8, vs2: u8 },
    VsraVI { vd: u8, imm: u8, vs2: u8 },
    VsllVI { vd: u8, imm: u8, vs2: u8 },
    VsrlVI { vd: u8, imm: u8, vs2: u8 },
    VandVI { vd: u8, imm: i8, vs2: u8 },
    VandVV { vd: u8, vs1: u8, vs2: u8 },
    VorVV { vd: u8, vs1: u8, vs2: u8 },
    VxorVV { vd: u8, vs1: u8, vs2: u8 },
    VslidedownVI { vd: u8, imm: u8, vs2: u8 },
    VslideupVI { vd: u8, imm: u8, vs2: u8 },

    // ----- Custom DIMC instructions (custom-0) -----
    /// DIMC Input-buffer Load: VRF[vs1 .. vs1+nvec) -> input buffer sector
    /// `sec`. `mask` holds one valid bit per source register; `width` is
    /// the reserved element-width hint field of Fig. 4 (unused by the
    /// timing model, carried for encoding fidelity).
    DlI { nvec: u8, mask: u8, vs1: u8, width: u8, sec: u8 },
    /// DIMC Memory Load: as `DL.I` but into row `m_row`.
    DlM { nvec: u8, mask: u8, vs1: u8, width: u8, sec: u8, m_row: u8 },
    /// DIMC Compute & Partial-sum store.
    DcP { sh: bool, dh: bool, m_row: u8, vs1: u8, width: u8, vd: u8 },
    /// DIMC Compute & Final-sum store (ReLU + requantize + nibble pack).
    DcF { sh: bool, dh: bool, m_row: u8, vs1: u8, width: u8, bidx: u8, vd: u8 },
}

/// Coarse instruction class, used for the paper's Fig. 6 operation
/// distribution (computing / loading / storing) and for FU assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InstrClass {
    Scalar,
    Branch,
    VectorAlu,
    VectorLoad,
    VectorStore,
    DimcLoad,
    DimcCompute,
    VConfig,
}

impl Instr {
    /// Classify for Fig.6 accounting and FU selection.
    pub fn class(&self) -> InstrClass {
        use Instr::*;
        match self {
            Lui { .. } | Auipc { .. } | OpImm { .. } | Op { .. } | Lw { .. } | Lbu { .. }
            | Sw { .. } | Sb { .. } | Jalr { .. } | Halt => InstrClass::Scalar,
            Branch { .. } | Jal { .. } => InstrClass::Branch,
            Vsetvli { .. } | Vsetivli { .. } => InstrClass::VConfig,
            Vle { .. } | Vlse { .. } => InstrClass::VectorLoad,
            Vse { .. } => InstrClass::VectorStore,
            DlI { .. } | DlM { .. } => InstrClass::DimcLoad,
            DcP { .. } | DcF { .. } => InstrClass::DimcCompute,
            _ => InstrClass::VectorAlu,
        }
    }

    /// True for the four custom DIMC instructions.
    pub fn is_custom(&self) -> bool {
        matches!(
            self.class(),
            InstrClass::DimcLoad | InstrClass::DimcCompute
        )
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Instr::*;
        match *self {
            Lui { rd, imm } => write!(f, "lui x{rd}, {imm:#x}"),
            Auipc { rd, imm } => write!(f, "auipc x{rd}, {imm:#x}"),
            OpImm { op, rd, rs1, imm } => {
                let m = match op {
                    AluOp::Add => "addi",
                    AluOp::Sll => "slli",
                    AluOp::Srl => "srli",
                    AluOp::Sra => "srai",
                    AluOp::And => "andi",
                    AluOp::Or => "ori",
                    AluOp::Xor => "xori",
                    AluOp::Slt => "slti",
                    AluOp::Sltu => "sltiu",
                    _ => "op?i",
                };
                write!(f, "{m} x{rd}, x{rs1}, {imm}")
            }
            Op { op, rd, rs1, rs2 } => {
                let m = match op {
                    AluOp::Add => "add",
                    AluOp::Sub => "sub",
                    AluOp::Sll => "sll",
                    AluOp::Srl => "srl",
                    AluOp::Sra => "sra",
                    AluOp::And => "and",
                    AluOp::Or => "or",
                    AluOp::Xor => "xor",
                    AluOp::Slt => "slt",
                    AluOp::Sltu => "sltu",
                    AluOp::Mul => "mul",
                };
                write!(f, "{m} x{rd}, x{rs1}, x{rs2}")
            }
            Lw { rd, rs1, imm } => write!(f, "lw x{rd}, {imm}(x{rs1})"),
            Lbu { rd, rs1, imm } => write!(f, "lbu x{rd}, {imm}(x{rs1})"),
            Sw { rs2, rs1, imm } => write!(f, "sw x{rs2}, {imm}(x{rs1})"),
            Sb { rs2, rs1, imm } => write!(f, "sb x{rs2}, {imm}(x{rs1})"),
            Branch { cond, rs1, rs2, off } => {
                let m = match cond {
                    BranchCond::Eq => "beq",
                    BranchCond::Ne => "bne",
                    BranchCond::Lt => "blt",
                    BranchCond::Ge => "bge",
                    BranchCond::Ltu => "bltu",
                    BranchCond::Geu => "bgeu",
                };
                write!(f, "{m} x{rs1}, x{rs2}, {off}")
            }
            Jal { rd, off } => write!(f, "jal x{rd}, {off}"),
            Jalr { rd, rs1, imm } => write!(f, "jalr x{rd}, {imm}(x{rs1})"),
            Halt => write!(f, "ecall"),
            Vsetvli { rd, rs1, vtype } => {
                write!(f, "vsetvli x{rd}, x{rs1}, e{},m{}", vtype.sew, vtype.lmul)
            }
            Vsetivli { rd, uimm, vtype } => {
                write!(f, "vsetivli x{rd}, {uimm}, e{},m{}", vtype.sew, vtype.lmul)
            }
            Vle { eew, vd, rs1 } => write!(f, "vle{eew}.v v{vd}, (x{rs1})"),
            Vse { eew, vs3, rs1 } => write!(f, "vse{eew}.v v{vs3}, (x{rs1})"),
            Vlse { eew, vd, rs1, rs2 } => write!(f, "vlse{eew}.v v{vd}, (x{rs1}), x{rs2}"),
            VaddVV { vd, vs1, vs2 } => write!(f, "vadd.vv v{vd}, v{vs2}, v{vs1}"),
            VaddVX { vd, rs1, vs2 } => write!(f, "vadd.vx v{vd}, v{vs2}, x{rs1}"),
            VaddVI { vd, imm, vs2 } => write!(f, "vadd.vi v{vd}, v{vs2}, {imm}"),
            VsubVV { vd, vs1, vs2 } => write!(f, "vsub.vv v{vd}, v{vs2}, v{vs1}"),
            VmulVV { vd, vs1, vs2 } => write!(f, "vmul.vv v{vd}, v{vs2}, v{vs1}"),
            VmaccVV { vd, vs1, vs2 } => write!(f, "vmacc.vv v{vd}, v{vs1}, v{vs2}"),
            VredsumVS { vd, vs1, vs2 } => write!(f, "vredsum.vs v{vd}, v{vs2}, v{vs1}"),
            VmvVI { vd, imm } => write!(f, "vmv.v.i v{vd}, {imm}"),
            VmvVX { vd, rs1 } => write!(f, "vmv.v.x v{vd}, x{rs1}"),
            VmvXS { rd, vs2 } => write!(f, "vmv.x.s x{rd}, v{vs2}"),
            VsextVf4 { vd, vs2 } => write!(f, "vsext.vf4 v{vd}, v{vs2}"),
            VmaxVX { vd, rs1, vs2 } => write!(f, "vmax.vx v{vd}, v{vs2}, x{rs1}"),
            VminVX { vd, rs1, vs2 } => write!(f, "vmin.vx v{vd}, v{vs2}, x{rs1}"),
            VsraVI { vd, imm, vs2 } => write!(f, "vsra.vi v{vd}, v{vs2}, {imm}"),
            VsllVI { vd, imm, vs2 } => write!(f, "vsll.vi v{vd}, v{vs2}, {imm}"),
            VsrlVI { vd, imm, vs2 } => write!(f, "vsrl.vi v{vd}, v{vs2}, {imm}"),
            VandVI { vd, imm, vs2 } => write!(f, "vand.vi v{vd}, v{vs2}, {imm}"),
            VandVV { vd, vs1, vs2 } => write!(f, "vand.vv v{vd}, v{vs2}, v{vs1}"),
            VorVV { vd, vs1, vs2 } => write!(f, "vor.vv v{vd}, v{vs2}, v{vs1}"),
            VxorVV { vd, vs1, vs2 } => write!(f, "vxor.vv v{vd}, v{vs2}, v{vs1}"),
            VslidedownVI { vd, imm, vs2 } => write!(f, "vslidedown.vi v{vd}, v{vs2}, {imm}"),
            VslideupVI { vd, imm, vs2 } => write!(f, "vslideup.vi v{vd}, v{vs2}, {imm}"),
            DlI { nvec, mask, vs1, width, sec } => {
                write!(f, "dl.i v{vs1}, nvec={nvec}, mask={mask:#06b}, w={width}, sec={sec}")
            }
            DlM { nvec, mask, vs1, width, sec, m_row } => write!(
                f,
                "dl.m v{vs1}, nvec={nvec}, mask={mask:#06b}, w={width}, sec={sec}, row={m_row}"
            ),
            DcP { sh, dh, m_row, vs1, width, vd } => write!(
                f,
                "dc.p v{vd}.{}, v{vs1}.{}, row={m_row}, w={width}",
                dh as u8, sh as u8
            ),
            DcF { sh, dh, m_row, vs1, width, bidx, vd } => write!(
                f,
                "dc.f v{vd}.{}[{bidx}], v{vs1}.{}, row={m_row}, w={width}",
                dh as u8, sh as u8
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vtype_roundtrip() {
        for sew in [8u16, 16, 32] {
            for lmul in [1u8, 2, 4, 8] {
                let vt = VType::new(sew, lmul);
                assert_eq!(VType::from_zimm(vt.zimm()), Some(vt));
            }
        }
    }

    #[test]
    fn vlmax() {
        assert_eq!(VType::new(8, 1).vlmax(), 8);
        assert_eq!(VType::new(32, 4).vlmax(), 8);
        assert_eq!(VType::new(8, 8).vlmax(), 64);
        assert_eq!(VType::new(32, 1).vlmax(), 2);
    }

    #[test]
    fn classes() {
        assert_eq!(
            Instr::DcF { sh: false, dh: true, m_row: 3, vs1: 1, width: 0, bidx: 2, vd: 9 }
                .class(),
            InstrClass::DimcCompute
        );
        assert_eq!(
            Instr::DlI { nvec: 4, mask: 0xf, vs1: 0, width: 0, sec: 1 }.class(),
            InstrClass::DimcLoad
        );
        assert!(Instr::DlI { nvec: 4, mask: 0xf, vs1: 0, width: 0, sec: 1 }.is_custom());
        assert_eq!(Instr::Halt.class(), InstrClass::Scalar);
        assert_eq!(
            Instr::Vle { eew: 8, vd: 1, rs1: 2 }.class(),
            InstrClass::VectorLoad
        );
    }
}
