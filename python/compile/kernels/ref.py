"""Pure-jnp oracle for the DIMC MAC kernel — the CORE correctness signal.

Implements the same semantics as ``dimc_mac`` with no Pallas: per-row-tile
24-bit wrapped accumulation, then the DC.F ReLU + shift + clamp stage.
pytest (`python/tests/test_kernel.py`) sweeps shapes and value ranges with
hypothesis and asserts exact equality.
"""

import jax.numpy as jnp

from .dimc_mac import ROW_ELEMS, wrap24


def ref_requant(acc, shift, relu, out_bits):
    v = jnp.maximum(acc, 0) if relu else acc
    v = v >> shift
    if relu:
        return jnp.clip(v, 0, (1 << out_bits) - 1)
    return jnp.clip(v, -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1)


def ref_dimc_matmul(patches, weights, *, shift=4, relu=True, out_bits=4, quantize=True):
    """Reference for ``dimc_mac.dimc_matmul`` (same padding requirements)."""
    p, k = patches.shape
    _, n = weights.shape
    assert k % ROW_ELEMS == 0
    acc = jnp.zeros((p, n), jnp.int32)
    for t in range(k // ROW_ELEMS):
        sl = slice(t * ROW_ELEMS, (t + 1) * ROW_ELEMS)
        prod = patches[:, sl].astype(jnp.int32) @ weights[sl, :].astype(jnp.int32)
        acc = wrap24(acc + prod)
    if quantize:
        acc = ref_requant(acc, shift, relu, out_bits)
    return acc


def ref_row_dot(ibuf, row, psum_in):
    """Reference for ``dimc_mac.dimc_row_dot``."""
    return wrap24(psum_in + ibuf.astype(jnp.int32) @ row.astype(jnp.int32))
