"""Layer-1 Pallas kernel: the DIMC tile's MAC array as a TPU-style kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
hot-spot is an SRAM MAC array, not a GPU kernel, but the same mapping rules
apply when expressing it for the MXU:

* the 1024-bit DIMC row (256 x 4-bit operands) becomes a K-dimension block
  of 256 lanes resident in VMEM — the software analogue of one row-tile;
* the 32-row bank becomes the N-dimension block (<= 32 output channels per
  group, exactly the DIMC kernel-capacity constraint);
* the sequential per-row accumulation pipeline becomes the innermost grid
  dimension, revisiting the output block with 24-bit wrapped accumulation
  (DC.P partial-sum chaining);
* DL.I sector loads become the BlockSpec HBM->VMEM schedule.

The kernel MUST run with ``interpret=True`` on this CPU image: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.

VMEM budget (estimated for a real TPU, DESIGN.md §Perf): one patch block
(8 x 256 x 4B = 8 KiB) + one weight tile (256 x 32 x 4B = 32 KiB) + one
output block (8 x 32 x 4B = 1 KiB) ~= 41 KiB, far below the ~16 MiB VMEM —
the schedule is bandwidth-bound on HBM exactly like the silicon tile is on
its 256-bit interface.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One DIMC row in 4-bit mode: 256 parallel MAC lanes.
ROW_ELEMS = 256
# The DIMC bank: 32 rows = 32 output channels per group.
GROUP_ROWS = 32
# Partial sums are 24-bit two's complement.
ACC_BITS = 24

_ACC_HALF = 1 << (ACC_BITS - 1)
_ACC_MASK = (1 << ACC_BITS) - 1


def wrap24(x: jax.Array) -> jax.Array:
    """Wrap an int32 array into 24-bit two's complement (sign-extended)."""
    return ((x + _ACC_HALF) & _ACC_MASK) - _ACC_HALF


def _requant(acc, shift, relu, out_bits):
    """The DC.F write-back stage: optional ReLU, scale, clamp."""
    v = jnp.maximum(acc, 0) if relu else acc
    v = v >> shift
    if relu:
        return jnp.clip(v, 0, (1 << out_bits) - 1)
    return jnp.clip(v, -(1 << (out_bits - 1)), (1 << (out_bits - 1)) - 1)


def _kernel(p_ref, w_ref, o_ref, *, tiles, shift, relu, out_bits, quantize):
    t = pl.program_id(2)  # innermost: the DC.P row-tile chain

    @pl.when(t == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(
        p_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = wrap24(o_ref[...] + prod)

    if quantize:

        @pl.when(t == tiles - 1)
        def _final():
            o_ref[...] = _requant(o_ref[...], shift, relu, out_bits)


@functools.partial(
    jax.jit, static_argnames=("shift", "relu", "out_bits", "quantize", "block_p")
)
def dimc_matmul(
    patches: jax.Array,
    weights: jax.Array,
    *,
    shift: int = 4,
    relu: bool = True,
    out_bits: int = 4,
    quantize: bool = True,
    block_p: int = 8,
) -> jax.Array:
    """DIMC-tile matmul: ``patches [P, K] @ weights [K, N]`` with 24-bit
    wrapped per-row-tile accumulation and the DC.F ReLU/requant stage.

    P must be a multiple of ``block_p``; K a multiple of 256 (row tiles);
    N a multiple of 32 (row groups). Pad with zeros to reach these — zero
    operands contribute nothing, exactly like the zero-padded DIMC rows.
    Returns int32 [P, N] (quantized nibble values when ``quantize``).
    """
    p, k = patches.shape
    k2, n = weights.shape
    assert k == k2, f"K mismatch {k} vs {k2}"
    assert p % block_p == 0, f"P={p} not a multiple of {block_p}"
    assert k % ROW_ELEMS == 0, f"K={k} not a multiple of {ROW_ELEMS}"
    assert n % GROUP_ROWS == 0, f"N={n} not a multiple of {GROUP_ROWS}"
    tiles = k // ROW_ELEMS
    grid = (p // block_p, n // GROUP_ROWS, tiles)
    return pl.pallas_call(
        functools.partial(
            _kernel,
            tiles=tiles,
            shift=shift,
            relu=relu,
            out_bits=out_bits,
            quantize=quantize,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, ROW_ELEMS), lambda i, g, t: (i, t)),
            pl.BlockSpec((ROW_ELEMS, GROUP_ROWS), lambda i, g, t: (t, g)),
        ],
        out_specs=pl.BlockSpec((block_p, GROUP_ROWS), lambda i, g, t: (i, g)),
        out_shape=jax.ShapeDtypeStruct((p, n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(patches, weights)


def dimc_row_dot(ibuf: jax.Array, row: jax.Array, psum_in: jax.Array) -> jax.Array:
    """One DC.P: 256-lane dot of the input buffer against one row, folded
    into the incoming partial sum with 24-bit wrap. Exported as the
    microcheck artifact (`dimc_row_golden`)."""
    d = jnp.dot(ibuf.astype(jnp.int32), row.astype(jnp.int32), preferred_element_type=jnp.int32)
    return wrap24(psum_in + d)
