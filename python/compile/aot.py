"""AOT entry point: lower the golden models to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the Rust ``xla`` crate) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Artifacts (shapes match `rust/src/coordinator/verify.rs`):

* ``conv_golden.hlo.txt``      — conv 2x2, 16->8 ch, 5x5 input, shift 4
* ``gemm_golden.hlo.txt``      — fc 64 -> 10, shift 4
* ``dimc_row_golden.hlo.txt``  — one DC.P row dot (256 lanes)

Usage: ``python -m compile.aot --out-dir ../artifacts`` (or just
``make artifacts`` from the repo root — a no-op when up to date).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The quickstart verification layer: conv 16ich -> 8och, 2x2, on 5x5.
CONV_SPEC = dict(h=5, w=5, ich=16, och=8, kh=2, kw=2, stride=1, pad=0, shift=4)
# The FC verification layer.
GEMM_SPEC = dict(k=64, och=10, shift=4)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_conv():
    s = CONV_SPEC
    x = jax.ShapeDtypeStruct((s["h"], s["w"], s["ich"]), jnp.int32)
    w = jax.ShapeDtypeStruct((s["och"], s["kh"], s["kw"], s["ich"]), jnp.int32)

    def fn(x, w):
        return (model.conv_golden(x, w, stride=s["stride"], pad=s["pad"], shift=s["shift"]),)

    return jax.jit(fn).lower(x, w)


def lower_gemm():
    s = GEMM_SPEC
    x = jax.ShapeDtypeStruct((s["k"],), jnp.int32)
    w = jax.ShapeDtypeStruct((s["och"], s["k"]), jnp.int32)

    def fn(x, w):
        return (model.gemm_golden(x, w, shift=s["shift"]),)

    return jax.jit(fn).lower(x, w)


def lower_row():
    v = jax.ShapeDtypeStruct((256,), jnp.int32)
    p = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(ibuf, row, psum):
        return (model.row_golden(ibuf, row, psum),)

    return jax.jit(fn).lower(v, v, p)


ARTIFACTS = {
    "conv_golden.hlo.txt": lower_conv,
    "gemm_golden.hlo.txt": lower_gemm,
    "dimc_row_golden.hlo.txt": lower_row,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars to {path}")


if __name__ == "__main__":
    main()
