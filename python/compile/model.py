"""Layer-2 JAX golden model: quantized conv / FC layers built on the L1
DIMC kernel, AOT-lowered to HLO text and executed from the Rust runtime to
cross-check the cycle simulator's functional outputs.

The numeric contract matches the simulator exactly:

* activations are unsigned ``precision``-bit values, weights signed;
* accumulation wraps at 24 bits per row-tile (modular arithmetic makes the
  final value independent of the zero-padded tile partition — the same
  argument that lets the Rust mapper pad kernels to register boundaries);
* DC.F write-back: ReLU, arithmetic shift, clamp to [0, 15].

Everything here is build-time only — Python never runs on the simulation
path.
"""

import jax
import jax.numpy as jnp

from .kernels.dimc_mac import GROUP_ROWS, ROW_ELEMS, dimc_matmul, dimc_row_dot


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: int) -> jax.Array:
    """Unfold ``x [H, W, C]`` into patches ``[OH*OW, KH*KW*C]``.

    Shapes are static at trace time, so plain Python loops lower to a fixed
    gather graph (fused by XLA into the surrounding matmul program).
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            sl = xp[ky : ky + oh * stride : stride, kx : kx + ow * stride : stride, :]
            cols.append(sl.reshape(oh * ow, c))
    # patch layout: (ky, kx) major, channel minor — the mapper's run order
    return jnp.concatenate(cols, axis=1)


def conv_golden(
    x: jax.Array, w: jax.Array, *, stride: int = 1, pad: int = 0, shift: int = 4
) -> jax.Array:
    """Quantized convolution through the DIMC kernel.

    ``x``: int32 [H, W, ICH] activations (unsigned 4-bit domain).
    ``w``: int32 [OCH, KH, KW, ICH] weights (signed 4-bit domain).
    Returns int32 [OH, OW, OCH] quantized outputs in [0, 15].
    """
    och, kh, kw, ich = w.shape
    h, wdt, _ = x.shape
    patches = im2col(x, kh, kw, stride, pad)  # [P, K]
    p, k = patches.shape
    # zero-pad to the kernel's granularity (rows / groups / patch blocks)
    kp = _round_up(k, ROW_ELEMS)
    np_ = _round_up(och, GROUP_ROWS)
    pp = _round_up(p, 8)
    patches = jnp.pad(patches, ((0, pp - p), (0, kp - k)))
    wmat = jnp.pad(w.reshape(och, k).T, ((0, kp - k), (0, np_ - och)))
    out = dimc_matmul(patches, wmat, shift=shift)
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wdt + 2 * pad - kw) // stride + 1
    return out[:p, :och].reshape(oh, ow, och)


def gemm_golden(x: jax.Array, w: jax.Array, *, shift: int = 4) -> jax.Array:
    """Quantized fully-connected layer: ``x`` int32 [K], ``w`` int32
    [OCH, K]; returns int32 [OCH]."""
    och, k = w.shape
    kp = _round_up(k, ROW_ELEMS)
    np_ = _round_up(och, GROUP_ROWS)
    patches = jnp.pad(x[None, :], ((0, 7), (0, kp - k)))
    wmat = jnp.pad(w.T, ((0, kp - k), (0, np_ - och)))
    return dimc_matmul(patches, wmat, shift=shift)[0, :och]


def row_golden(ibuf: jax.Array, row: jax.Array, psum_in: jax.Array) -> jax.Array:
    """One DC.P row dot (the microcheck artifact)."""
    return dimc_row_dot(ibuf, row, psum_in)
