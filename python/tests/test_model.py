"""L2 correctness: conv/gemm golden models — shapes, im2col layout, and
agreement with a direct (non-tiled) integer convolution."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.dimc_mac import wrap24
from compile.model import conv_golden, gemm_golden, im2col


def direct_conv_q(x, w, stride, pad, shift):
    """Direct int32 conv + the DC.F requant — independent of im2col and of
    the kernel's tiling (valid because wrap24 is modular arithmetic)."""
    och, kh, kw, ich = w.shape
    h, wd, _ = x.shape
    xp = np.pad(np.asarray(x), ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    out = np.zeros((oh, ow, och), np.int64)
    for oy in range(oh):
        for ox in range(ow):
            patch = xp[oy * stride : oy * stride + kh, ox * stride : ox * stride + kw, :]
            for oc in range(och):
                out[oy, ox, oc] = int((patch.astype(np.int64) * np.asarray(w)[oc]).sum())
    acc = np.asarray(wrap24(jnp.asarray(out, jnp.int32)))
    return np.clip(np.maximum(acc, 0) >> shift, 0, 15)


@settings(max_examples=10, deadline=None)
@given(
    ich=st.sampled_from([3, 8, 16]),
    och=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2, 3]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31),
)
def test_conv_golden_matches_direct(ich, och, k, stride, pad, seed):
    rng = np.random.default_rng(seed)
    h = 6
    x = jnp.asarray(rng.integers(0, 16, (h, h, ich)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (och, k, k, ich)), jnp.int32)
    got = np.asarray(conv_golden(x, w, stride=stride, pad=pad, shift=4))
    want = direct_conv_q(x, w, stride, pad, 4)
    np.testing.assert_array_equal(got, want)


def test_im2col_layout_is_run_major():
    # element order inside a patch must be (ky, kx) major, channel minor —
    # the same run order the Rust mapper uses.
    x = jnp.arange(2 * 3 * 2, dtype=jnp.int32).reshape(2, 3, 2)
    p = im2col(x, 2, 2, 1, 0)  # oh=1, ow=2, K=8
    assert p.shape == (2, 8)
    first = np.asarray(p[0])
    want = np.concatenate(
        [np.asarray(x[0, 0]), np.asarray(x[0, 1]), np.asarray(x[1, 0]), np.asarray(x[1, 1])]
    )
    np.testing.assert_array_equal(first, want)


def test_gemm_golden_shapes_and_values():
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.integers(0, 16, (64,)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (10, 64)), jnp.int32)
    got = np.asarray(gemm_golden(x, w, shift=4))
    acc = np.asarray(w, np.int64) @ np.asarray(x, np.int64)
    want = np.clip(np.maximum(acc, 0) >> 4, 0, 15)
    np.testing.assert_array_equal(got, want.astype(np.int32))


def test_conv_golden_fc_shaped_input():
    # a 1x1 spatial conv behaves like the FC path
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 16, (1, 1, 300)), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, (40, 1, 1, 300)), jnp.int32)
    got = np.asarray(conv_golden(x, w, shift=4))
    assert got.shape == (1, 1, 40)
    want = np.asarray(gemm_golden(x.reshape(300), w.reshape(40, 300), shift=4))
    np.testing.assert_array_equal(got.reshape(40), want)
