"""L1 correctness: the Pallas DIMC kernel against the pure-jnp oracle.

hypothesis sweeps shapes (patch blocks, row tiles, row groups) and value
ranges (int4 domain plus adversarial wide values that force 24-bit wrap);
every case must match ``ref.py`` exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dimc_mac import (
    GROUP_ROWS,
    ROW_ELEMS,
    dimc_matmul,
    dimc_row_dot,
    wrap24,
)
from compile.kernels.ref import ref_dimc_matmul, ref_requant, ref_row_dot


def _rand(rng, shape, lo, hi):
    return jnp.asarray(rng.integers(lo, hi, size=shape, dtype=np.int64), jnp.int32)


@settings(max_examples=25, deadline=None)
@given(
    pb=st.integers(1, 3),  # patch blocks of 8
    tiles=st.integers(1, 3),  # row tiles (K = 256 * tiles)
    groups=st.integers(1, 2),  # row groups (N = 32 * groups)
    shift=st.integers(0, 10),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_ref_int4_domain(pb, tiles, groups, shift, seed):
    rng = np.random.default_rng(seed)
    p, k, n = 8 * pb, ROW_ELEMS * tiles, GROUP_ROWS * groups
    patches = _rand(rng, (p, k), 0, 16)  # unsigned activations
    weights = _rand(rng, (k, n), -8, 8)  # signed weights
    got = dimc_matmul(patches, weights, shift=shift)
    want = ref_dimc_matmul(patches, weights, shift=shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(jnp.min(got)) >= 0 and int(jnp.max(got)) <= 15


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(1, 4),
    relu=st.booleans(),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_wraps_at_24_bits(tiles, relu, seed):
    # Wide adversarial values force the accumulator through the wrap.
    rng = np.random.default_rng(seed)
    p, k, n = 8, ROW_ELEMS * tiles, GROUP_ROWS
    patches = _rand(rng, (p, k), -3000, 3000)
    weights = _rand(rng, (k, n), -3000, 3000)
    got = dimc_matmul(patches, weights, shift=0, relu=relu, quantize=False)
    want = ref_dimc_matmul(patches, weights, shift=0, relu=relu, quantize=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # wrapped psums stay inside the 24-bit domain
    assert int(jnp.max(jnp.abs(got))) <= 1 << 23


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), psum=st.integers(-(1 << 23), (1 << 23) - 1))
def test_row_dot_matches_ref(seed, psum):
    rng = np.random.default_rng(seed)
    ibuf = _rand(rng, (256,), 0, 16)
    row = _rand(rng, (256,), -8, 8)
    p = jnp.int32(psum)
    got = dimc_row_dot(ibuf, row, p)
    want = ref_row_dot(ibuf, row, p)
    assert int(got) == int(want)


def test_wrap24_fixed_points():
    vals = jnp.array([0, 1, -1, (1 << 23) - 1, 1 << 23, -(1 << 23) - 1, 1 << 24], jnp.int32)
    got = wrap24(vals)
    want = jnp.array([0, 1, -1, (1 << 23) - 1, -(1 << 23), (1 << 23) - 1, 0], jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requant_corners():
    acc = jnp.array([-100, -1, 0, 15, 16, 1 << 20], jnp.int32)
    got = ref_requant(acc, 0, True, 4)
    np.testing.assert_array_equal(np.asarray(got), [0, 0, 0, 15, 15, 15])
    got = ref_requant(acc, 2, True, 4)
    np.testing.assert_array_equal(np.asarray(got), [0, 0, 0, 3, 4, 15])


def test_zero_padding_is_neutral():
    # Padding K with zeros must not change results (the mapper relies on
    # this when aligning kernels to row tiles).
    rng = np.random.default_rng(0)
    p = _rand(rng, (8, ROW_ELEMS), 0, 16)
    w = _rand(rng, (ROW_ELEMS, GROUP_ROWS), -8, 8)
    base = dimc_matmul(p, w, shift=3)
    p2 = jnp.pad(p, ((0, 0), (0, ROW_ELEMS)))
    w2 = jnp.pad(w, ((0, ROW_ELEMS), (0, 0)))
    padded = dimc_matmul(p2, w2, shift=3)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(padded))


@pytest.mark.parametrize("bad_k", [100, 257])
def test_rejects_unaligned_k(bad_k):
    with pytest.raises(AssertionError):
        dimc_matmul(jnp.zeros((8, bad_k), jnp.int32), jnp.zeros((bad_k, 32), jnp.int32))
