//! Assembly playground: hand-write a DIMC program with the four custom
//! instructions, inspect its encoding (Fig. 4, custom-0 space), and run it
//! on the simulated core.
//!
//! ```sh
//! cargo run --release --example asm_playground
//! ```

use dimc_rvv::arch::Arch;
use dimc_rvv::isa::{asm, decode::decode, encode::encode};
use dimc_rvv::pipeline::core::Core;
use dimc_rvv::pipeline::vrf::read_half;

const PROGRAM: &str = r"
    # --- a hand-written DIMC dot product ---------------------------
    # acts at 0x100 (16 nibbles), weights at 0x200; one DC.P row dot.
    li   x5, 8
    vsetvli x0, x5, e8, m1
    li   x10, 0x100
    li   x11, 0x200
    vle8.v v1, (x10)            # 8 bytes = 16 int4 activations
    vle8.v v2, (x11)            # 16 int4 weights
    dl.i v1, nvec=1, mask=0b1, sec=0        # VRF -> input buffer
    dl.m v2, nvec=1, mask=0b1, sec=0, row=5 # VRF -> memory row 5
    vmv.v.i v6, 0                           # zero partial sum
    dc.p v8.0, v6.0, row=5, w=0             # in-memory MAC
    ecall
";

fn main() {
    let prog = asm::assemble(PROGRAM).expect("assembly");
    println!("assembled {} instructions:\n", prog.len());
    println!("{:>10}  {:<40} {}", "encoding", "disassembly", "class");
    for i in &prog {
        let word = encode(i);
        assert_eq!(decode(word).unwrap(), *i, "encode/decode must round-trip");
        let disasm = i.to_string();
        println!("{word:#010x}  {disasm:<40} {:?}", i.class());
    }

    // place data: acts nibbles 1..=8 twice, weights all 2
    let mut core = Core::new(Arch::default());
    core.dimc.cfg.requant_shift = 0;
    let acts: Vec<u8> = (0..8).map(|i| (((i % 8) + 1) << 4 | ((i % 8) + 1)) as u8).collect();
    core.mem.write_direct(0x100, &acts);
    core.mem.write_direct(0x200, &[0x22u8; 8]);

    let stats = core.run(&prog, 10_000).expect("run");
    let psum = read_half(&core.vregs, 8, false) as i32;
    let expect: i32 = (1..=8).map(|v| 2 * v).sum::<i32>() * 2;
    println!("\nran in {} cycles ({} instructions)", stats.cycles, stats.instret);
    println!("DC.P partial sum in v8.0 = {psum} (expected {expect})");
    assert_eq!(psum, expect);
    println!("DIMC stats: {:?}", core.dimc.stats);
}
