//! Quickstart: compile one conv layer for both cores, simulate, and print
//! the paper's three metrics (GOPS, speedup, area-normalized speedup).
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dimc_rvv::arch::Arch;
use dimc_rvv::compiler::layer::LayerConfig;
use dimc_rvv::compiler::pack::{synth_acts, synth_wts};
use dimc_rvv::coordinator::driver::{
    reference_outputs, run_functional, simulate_layer_timed, Engine, Timing,
};
use dimc_rvv::dimc::Precision;
use dimc_rvv::metrics::area::AreaModel;

fn main() {
    // A ResNet-style bottleneck layer: 1x1, 64 -> 64 channels on 56x56.
    let layer = LayerConfig::conv("demo", 64, 64, 1, 1, 56, 56, 1, 0);
    println!("layer: {layer}");
    println!("  {} MACs, {} output positions", layer.macs(), layer.patches());

    // --- timing on both engines ---
    let sim = |engine| {
        simulate_layer_timed(&layer, engine, Precision::Int4, Arch::default(), Timing::Interpreter)
    };
    let dimc = sim(Engine::Dimc).expect("dimc sim");
    let base = sim(Engine::Baseline).expect("baseline sim");
    let speedup = base.cycles as f64 / dimc.cycles as f64;
    let area = AreaModel::default();
    println!("\ntiming @500 MHz:");
    println!("  DIMC-RVV : {:>12} cycles  ({:.1} GOPS)", dimc.cycles, dimc.gops());
    println!("  baseline : {:>12} cycles  ({:.1} GOPS)", base.cycles, base.gops());
    println!("  speedup  : {speedup:.1}x   area-normalized: {:.1}x", area.ans(speedup));

    // --- functional execution on a smaller sibling (bit-exact check) ---
    let small = LayerConfig::conv("demo-small", 64, 32, 1, 1, 8, 8, 1, 0);
    let acts = synth_acts(&small, Precision::Int4, 42);
    let wts = synth_wts(&small, Precision::Int4, 42);
    let run = run_functional(&small, Engine::Dimc, &acts, &wts, 4).expect("functional");
    let want = reference_outputs(&small, Engine::Dimc, &acts, &wts, 4);
    assert_eq!(run.outputs, want, "simulator disagrees with the conv oracle");
    println!("\nfunctional check: {} outputs bit-match the oracle OK", want.len());
    println!("first output row: {:?}", &run.outputs[..8]);
}
