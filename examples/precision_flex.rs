//! Precision reconfiguration: the same DIMC hardware performs 256 x 4-bit,
//! 512 x 2-bit or 1024 x 1-bit MACs per cycle (paper §III). This example
//! sweeps one layer across the three modes and shows the accuracy /
//! efficiency trade-off knob: lower precision doubles the lanes (and the
//! theoretical GOPS) while halving kernel row footprints (fewer tiles).
//!
//! ```sh
//! cargo run --release --example precision_flex
//! ```

use dimc_rvv::arch::Arch;
use dimc_rvv::compiler::layer::LayerConfig;
use dimc_rvv::coordinator::driver::{simulate_layer_timed, Engine, Timing};
use dimc_rvv::dimc::Precision;

fn main() {
    let layer = LayerConfig::conv("flex", 128, 32, 3, 3, 28, 28, 1, 1);
    println!("layer: {layer}  ({} MACs)\n", layer.macs());
    println!(
        "{:<6} {:>6} {:>7} {:>12} {:>9} {:>10} {:>11}",
        "mode", "lanes", "tiles", "cycles", "GOPS", "peak GOPS", "utilization"
    );
    let arch = Arch::default();
    for p in [Precision::Int4, Precision::Int2, Precision::Int1] {
        let r = simulate_layer_timed(&layer, Engine::Dimc, p, arch, Timing::Interpreter)
            .expect("sim");
        let peak = arch.dimc_peak_gops(p.bits());
        println!(
            "INT{:<3} {:>6} {:>7} {:>12} {:>9.1} {:>10.0} {:>10.1}%",
            p.bits(),
            p.lanes(),
            layer.tiles(p),
            r.cycles,
            r.gops(),
            peak,
            100.0 * r.gops() / peak
        );
    }
    println!(
        "\nLower precision halves each kernel's row footprint (fewer tile\n\
         passes) and doubles MAC lanes — the scalable accuracy/efficiency\n\
         trade-off the paper's reconfigurable tile provides."
    );
}
