//! End-to-end driver (the EXPERIMENTS.md headline run): proves all three
//! layers compose on a real workload.
//!
//! 1. **Golden cross-check** — the cycle simulator's functional outputs
//!    (Rust pipeline + DIMC tile executing the custom instruction stream)
//!    against the AOT-compiled JAX/Pallas golden model executed through
//!    PJRT (`artifacts/*.hlo.txt`, built once by `make artifacts`).
//! 2. **Full ResNet-50 inference simulation** on both the DIMC-enhanced
//!    and the baseline RVV core, layer by layer, reporting the paper's
//!    metrics (Fig. 5 GOPS, Fig. 7 speedup/ANS) and the network totals.
//!
//! ```sh
//! make artifacts && cargo run --release --example resnet50_e2e
//! ```

use dimc_rvv::coordinator::figures::resnet50_rows;
use dimc_rvv::coordinator::verify;
use dimc_rvv::metrics::report::summarize;

fn main() {
    // --- [1] three-layer composition proof ---
    println!("[1/2] golden cross-check (simulator vs JAX/Pallas via PJRT)");
    match verify::verify_all(&[1, 2, 3]) {
        Ok(reports) => {
            for r in &reports {
                assert!(r.ok(), "{} mismatched {} of {} outputs", r.layer, r.mismatches, r.outputs);
                println!(
                    "  {:<12} {:>4}/{:<4} outputs match ({} sim cycles)",
                    r.layer,
                    r.outputs - r.mismatches,
                    r.outputs,
                    r.sim_cycles
                );
            }
            println!("  all {} cross-checks passed", reports.len());
        }
        Err(e) => {
            eprintln!("  SKIPPED ({e}) — run `make artifacts` for the full check");
        }
    }

    // --- [2] full-network simulation ---
    println!("\n[2/2] ResNet-50, all 53 conv layers + fc, both engines");
    let rows = resnet50_rows().expect("simulation");
    println!("{:<14} {:>8} {:>9} {:>8}", "layer", "GOPS", "speedup", "ANS");
    for r in &rows {
        println!("{:<14} {:>8.1} {:>8.1}x {:>7.1}x", r.name, r.gops, r.speedup, r.ans);
    }
    let s = summarize(&rows);
    let dimc: u64 = rows.iter().map(|r| r.dimc_cycles).sum();
    let base: u64 = rows.iter().map(|r| r.baseline_cycles).sum();
    let ops: u64 = rows.iter().map(|r| r.ops).sum();
    println!("\nnetwork totals @500 MHz:");
    println!("  ops          : {:.2} G", ops as f64 / 1e9);
    println!(
        "  DIMC-RVV     : {:>13} cycles = {:>8.2} ms  ({:.1} GOPS sustained)",
        dimc,
        dimc as f64 / 5e5,
        ops as f64 / (dimc as f64 / 5e8) / 1e9
    );
    println!("  baseline RVV : {:>13} cycles = {:>8.2} ms", base, base as f64 / 5e5);
    println!("  network speedup: {:.0}x", base as f64 / dimc as f64);
    println!("\nheadline vs paper:");
    println!("  peak GOPS    : {:>6.1}  (paper: 137)", s.peak_gops);
    println!("  peak speedup : {:>5.0}x  (paper: 217x)", s.peak_speedup);
    println!("  ANS          : up to {:.0}x (paper: >50x)", s.peak_ans);
}
